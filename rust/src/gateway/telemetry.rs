//! Fleet telemetry: the paper's three device-workload metrics (DASI /
//! CPQ / Phi) per interned [`DevIdx`], sourced from the roofline, power,
//! and RC-thermal models plus the [`EnergyTable`] memory substrate.
//!
//! - **DASI** — roofline-derived compute utilization of the decode task
//!   on the device (attained FLOP time over total roofline time): the
//!   workload's utilization signature, static per `(fleet, shape)`.
//! - **CPQ** — memory pressure: resident stage memory demanded by the
//!   model (embedding + layers + LM head, from the [`EnergyTable`])
//!   over the device's capacity.
//! - **Phi** — thermal yield: the guard's Eq. 8 workload factor in
//!   [0, 1], quantized into the same 4 shedding bands the plan cache
//!   invalidates on ([`crate::safety::thermal_guard::ThermalDecision`]).
//!
//! [`TelemetryProbe`] owns the evolving per-device thermal state, a
//! [`DeviceHealth`] FSM per device (PR 5: a Failed device flips
//! `schedulable` and so reroutes the executor lanes — failures, not
//! just thermal bands, move the route), and the [`ShedTracker`] band
//! counters. The gateway's `safety_version` is the sum of every
//! device's shed AND health version counters — the monotone staleness
//! signal route decisions key on (the PR-3 plan-cache consumer
//! contract: a version bump invalidates the consumer's current plan,
//! never the telemetry history).
//!
//! The probe can also host the PR-5 calibration estimators
//! ([`TelemetryProbe::enable_calibration`]): the serve path feeds
//! measured executor (time, energy) samples against the snapshot's
//! predicted coefficients through [`TelemetryProbe::record_measured`],
//! so the same residual→RLS→Page-Hinkley loop the sim closes runs on
//! live traffic.

use crate::calibration::{CalibrationStats, FleetCalibrator};
use crate::coordinator::allocation::ModelShape;
use crate::coordinator::disaggregation::{decode_task, prefill_task};
use crate::coordinator::energy_table::{EnergyTable, StageKind};
use crate::devices::fleet::Fleet;
use crate::devices::power::PowerModel;
use crate::devices::spec::{DevIdx, DeviceSpec};
use crate::devices::thermal::ThermalState;
use crate::safety::health::{DeviceHealth, HealthState};
use crate::safety::thermal_guard::{ShedTracker, ThermalGuard};

/// Prompt length the per-token prefill cost is normalized at.
const PREFILL_UNIT_TOKENS: u32 = 32;

/// One device's telemetry at one instant, plus the static service-cost
/// coefficients the wave scheduler prices dispatches with.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceTelemetry {
    pub dev: DevIdx,
    /// Roofline compute utilization of the decode task in [0, 1].
    pub dasi: f64,
    /// Resident model memory over device capacity (can exceed 1 when
    /// the model does not fit).
    pub cpq: f64,
    /// Thermal yield: guard workload factor in [0, 1] (1 = cool).
    pub phi: f64,
    /// Quantized shedding band (0..=SHED_LEVELS) of `phi`.
    pub shed_level: u8,
    pub temp_c: f64,
    pub schedulable: bool,
    /// Unthrottled roofline seconds of one decode step.
    pub step_s: f64,
    /// Unthrottled prefill seconds per prompt token.
    pub prefill_unit_s: f64,
    /// Active draw (W) while decoding.
    pub active_power_w: f64,
}

/// A rolling snapshot of the whole fleet. Snapshots are cheap value
/// types: the gateway refreshes one at the telemetry cadence and every
/// admission/dispatch decision reads the same frozen view, which keeps
/// runs bit-deterministic under the logical clock.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetTelemetry {
    /// Logical time the snapshot was taken (s).
    pub at_s: f64,
    /// Monotone safety-state version (Σ per-device shed versions) at
    /// snapshot time.
    pub safety_version: u64,
    /// One entry per fleet device, in fleet (interned index) order.
    pub devices: Vec<DeviceTelemetry>,
}

impl FleetTelemetry {
    pub fn device(&self, dev: DevIdx) -> Option<&DeviceTelemetry> {
        self.devices.get(dev.as_usize()).filter(|d| d.dev == dev)
    }
}

#[derive(Debug, Clone)]
struct ProbeDevice {
    spec: DeviceSpec,
    thermal: ThermalState,
    shed: ShedTracker,
    /// Health FSM: Failed devices are unschedulable, which the lane
    /// router reads off the snapshot — a failure reroutes lanes.
    health: DeviceHealth,
    /// Roofline class of the decode task on this device (classifies
    /// serve-path residual samples for the calibrator).
    decode_memory_bound: bool,
    dasi: f64,
    cpq: f64,
    step_s: f64,
    prefill_unit_s: f64,
    active_power_w: f64,
    /// Active seconds/joules accumulated since the last `advance`.
    window_busy_s: f64,
    window_energy_j: f64,
    busy_s: f64,
    energy_j: f64,
    idle_j: f64,
}

/// Evolving telemetry source: integrates recorded busy work into the RC
/// thermal model on an injected logical clock and emits
/// [`FleetTelemetry`] snapshots. No wall time anywhere.
#[derive(Debug, Clone)]
pub struct TelemetryProbe {
    guard: ThermalGuard,
    devices: Vec<ProbeDevice>,
    /// PR-5 online calibration estimators (`None` until
    /// [`TelemetryProbe::enable_calibration`]).
    calibrator: Option<FleetCalibrator>,
}

impl TelemetryProbe {
    /// Evaluate the static per-device coefficients once (roofline +
    /// power model + [`EnergyTable`] memory demand) and start every
    /// device cold at ambient.
    pub fn new(fleet: &Fleet, shape: &ModelShape) -> TelemetryProbe {
        let table = EnergyTable::build(fleet, shape);
        let d_task = decode_task(shape);
        let p_task = prefill_task(shape, PREFILL_UNIT_TOKENS);
        let resident_gb = table.mem_gb(StageKind::Embedding)
            + table.n_layers() as f64 * table.mem_gb(StageKind::Layer)
            + table.mem_gb(StageKind::LmHead);
        let devices = fleet
            .devices()
            .iter()
            .enumerate()
            .map(|(i, spec)| ProbeDevice {
                thermal: ThermalState::new(spec),
                shed: ShedTracker::default(),
                health: DeviceHealth::new(spec.id.clone()),
                decode_memory_bound: d_task.memory_bound_on(spec),
                dasi: d_task.compute_utilization(spec),
                cpq: resident_gb / table.capacity_gb(DevIdx(i as u16)).max(1e-9),
                step_s: d_task.seconds_on(spec, 1.0),
                prefill_unit_s: p_task.seconds_on(spec, 1.0) / PREFILL_UNIT_TOKENS as f64,
                active_power_w: PowerModel::active_power_for(spec, &d_task),
                window_busy_s: 0.0,
                window_energy_j: 0.0,
                busy_s: 0.0,
                energy_j: 0.0,
                idle_j: 0.0,
                spec: spec.clone(),
            })
            .collect();
        TelemetryProbe { guard: ThermalGuard::default(), devices, calibrator: None }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Attach the PR-5 calibration estimators: subsequent
    /// [`TelemetryProbe::record_measured`] calls feed them.
    pub fn enable_calibration(&mut self) {
        if self.calibrator.is_none() {
            self.calibrator = Some(FleetCalibrator::new(self.devices.len()));
        }
    }

    pub fn calibrator(&self) -> Option<&FleetCalibrator> {
        self.calibrator.as_ref()
    }

    /// Serve-path calibration stats (`None` until enabled).
    pub fn calibration_stats(&self) -> Option<CalibrationStats> {
        self.calibrator.as_ref().map(|c| c.stats())
    }

    /// Mark a device Failed: it leaves every subsequent snapshot's
    /// schedulable set (the lane router reroutes on the version bump)
    /// and stops absorbing admission pressure.
    pub fn mark_failed(&mut self, dev: DevIdx, now_s: f64) {
        self.devices[dev.as_usize()].health.mark_failed(now_s);
    }

    /// Driver reset succeeded: Failed → Recovering (schedulable again
    /// at reduced capacity; another version bump reroutes the lanes
    /// back).
    pub fn mark_recovering(&mut self, dev: DevIdx, now_s: f64) {
        self.devices[dev.as_usize()].health.mark_recovering(now_s);
    }

    pub fn health(&self, dev: DevIdx) -> HealthState {
        self.devices[dev.as_usize()].health.state()
    }

    /// One measured executor sample for work served on `dev`: records
    /// the busy work (as [`TelemetryProbe::record_busy`]) AND feeds the
    /// predicted-vs-measured residual to the calibrator when enabled.
    /// `predicted_*` are priced from the snapshot's NAMEPLATE
    /// coefficients (the ones the dispatch decision used); the applied
    /// calibration overlay is folded in here, so the residual is
    /// measured against the CURRENT model — the `observe_task`
    /// contract. Without that fold, a sustained executor-vs-model bias
    /// would re-fire the detector after every recalibration and
    /// compound the scales geometrically toward the clamp bounds.
    pub fn record_measured(
        &mut self,
        dev: DevIdx,
        predicted_s: f64,
        measured_s: f64,
        predicted_j: f64,
        measured_j: f64,
    ) {
        let memory_bound = self.devices[dev.as_usize()].decode_memory_bound;
        self.record_busy(dev, measured_s, measured_j);
        if let Some(cal) = &mut self.calibrator {
            let overlay = *cal.overlay(dev);
            let time_scale = if memory_bound {
                overlay.bandwidth_scale
            } else {
                overlay.compute_scale
            };
            // A slower effective coefficient (scale < 1) means the
            // applied model predicts proportionally MORE seconds.
            let pred_s = predicted_s / time_scale.max(1e-9);
            let pred_j = predicted_j / time_scale.max(1e-9) * overlay.power_scale;
            cal.observe_task(dev, memory_bound, pred_s, measured_s, pred_j, measured_j);
        }
    }

    /// Attribute active work to a device: `busy_s` seconds drawing
    /// `energy_j` joules, integrated into the thermal model at the next
    /// [`TelemetryProbe::advance`].
    pub fn record_busy(&mut self, dev: DevIdx, busy_s: f64, energy_j: f64) {
        let d = &mut self.devices[dev.as_usize()];
        d.window_busy_s += busy_s;
        d.window_energy_j += energy_j;
        d.busy_s += busy_s;
        d.energy_j += energy_j;
    }

    /// Advance the logical clock by `dt_s`: each device consumes up to
    /// `dt_s` of its recorded busy backlog (work is committed ahead at
    /// dispatch time, so the window carries the remainder forward),
    /// integrates the window's mean power (active + idle share,
    /// TDP-capped) through the RC model, then observes its shedding
    /// band — a band crossing bumps the device's monotone version.
    /// Carrying the backlog keeps a lane's serial commitment heating it
    /// for the whole service interval and keeps idle draw off seconds
    /// the lane is actually busy.
    pub fn advance(&mut self, dt_s: f64) {
        if dt_s <= 0.0 {
            return;
        }
        for d in &mut self.devices {
            let busy_s = d.window_busy_s.min(dt_s);
            let active_j = if d.window_busy_s > 0.0 {
                d.window_energy_j * (busy_s / d.window_busy_s)
            } else {
                0.0
            };
            let idle_s = dt_s - busy_s;
            let idle_j = d.spec.idle_w * idle_s;
            let mean_w = ((active_j + idle_j) / dt_s).min(d.spec.tdp_w);
            d.thermal.step(&d.spec, mean_w, dt_s);
            d.idle_j += idle_j;
            d.window_busy_s -= busy_s;
            d.window_energy_j -= active_j;
            let decision = self.guard.evaluate(&d.spec, d.thermal.temp_c());
            d.shed.observe(decision.shed_level());
        }
    }

    /// Advance by `dt_s` in `chunk_s` slices while recorded busy
    /// backlog remains (shed bands must keep updating as committed
    /// work heats a device), then fast-forward the idle remainder in
    /// ONE exact step: the RC solution is exact at constant power and
    /// an idle fleet draws constant idle power, so the temperature is
    /// bit-identical to chunked stepping — only cool-down band
    /// crossings coalesce into the single step's observation (the same
    /// coalescing semantic safety transitions already have). This is
    /// what keeps a sparse trace (hours of idle logical time between
    /// arrivals) from grinding through millions of no-op chunks.
    pub fn advance_chunked(&mut self, dt_s: f64, chunk_s: f64) {
        let chunk = chunk_s.max(1e-6);
        let mut remaining = dt_s;
        while remaining > 0.0 {
            if !self.has_pending_work() {
                self.advance(remaining);
                return;
            }
            let step = remaining.min(chunk);
            self.advance(step);
            remaining -= step;
        }
    }

    /// Any device still carrying committed-but-unintegrated busy work.
    fn has_pending_work(&self) -> bool {
        self.devices.iter().any(|d| d.window_busy_s > 0.0)
    }

    /// Monotone safety-state version: the sum of every device's shed
    /// AND health version counters. Constant exactly while no band
    /// crossing and no health transition occurs — so a device failure
    /// invalidates the lane route exactly like a thermal band change
    /// (the PR-4 ROADMAP knob, closed in PR 5).
    pub fn safety_version(&self) -> u64 {
        self.devices.iter().map(|d| d.shed.version() + d.health.version()).sum()
    }

    pub fn snapshot(&self, at_s: f64) -> FleetTelemetry {
        let devices = self
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let decision = self.guard.evaluate(&d.spec, d.thermal.temp_c());
                DeviceTelemetry {
                    dev: DevIdx(i as u16),
                    dasi: d.dasi,
                    cpq: d.cpq,
                    phi: decision.workload_factor,
                    shed_level: decision.shed_level(),
                    temp_c: d.thermal.temp_c(),
                    schedulable: d.health.state().schedulable(),
                    step_s: d.step_s,
                    prefill_unit_s: d.prefill_unit_s,
                    active_power_w: d.active_power_w,
                }
            })
            .collect();
        FleetTelemetry { at_s, safety_version: self.safety_version(), devices }
    }

    /// Best-case (unthrottled, unloaded, fastest device) service seconds
    /// for a request — the scale SLA deadlines are set on.
    pub fn unloaded_service_s(&self, prompt_tokens: u32, output_tokens: u32) -> f64 {
        self.devices
            .iter()
            .map(|d| {
                prompt_tokens as f64 * d.prefill_unit_s + output_tokens as f64 * d.step_s
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Total energy attributed so far (active + idle), J.
    pub fn total_energy_j(&self) -> f64 {
        self.devices.iter().map(|d| d.energy_j + d.idle_j).sum()
    }

    pub fn idle_energy_j(&self) -> f64 {
        self.devices.iter().map(|d| d.idle_j).sum()
    }

    /// Per-device active busy seconds, in fleet order.
    pub fn busy_seconds(&self) -> Vec<(String, f64)> {
        self.devices.iter().map(|d| (d.spec.id.0.clone(), d.busy_s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::fleet::FleetPreset;
    use crate::experiments::runner::default_meta;
    use crate::workload::datasets::ModelFamily;

    fn probe(preset: FleetPreset) -> TelemetryProbe {
        let fleet = Fleet::preset(preset);
        let shape = ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2));
        TelemetryProbe::new(&fleet, &shape)
    }

    #[test]
    fn cold_fleet_has_full_thermal_yield() {
        let p = probe(FleetPreset::EdgeBox);
        let snap = p.snapshot(0.0);
        assert_eq!(snap.safety_version, 0);
        for d in &snap.devices {
            assert_eq!(d.phi, 1.0, "{:?} must start cool", d.dev);
            assert_eq!(d.shed_level, 0);
            assert!((0.0..=1.0).contains(&d.dasi));
            assert!(d.cpq > 0.0 && d.cpq < 1.0, "gpt2 fits every edge device");
            assert!(d.step_s > 0.0 && d.prefill_unit_s > 0.0);
        }
    }

    #[test]
    fn sustained_heat_crosses_bands_and_bumps_version() {
        let mut p = probe(FleetPreset::GpuOnly);
        let v0 = p.safety_version();
        // Slam the single lane with compute-grade draw for minutes of
        // logical time: the guard must start shedding and the version
        // must move exactly with band crossings.
        for _ in 0..600 {
            let spec_tdp = 300.0;
            p.record_busy(DevIdx(0), 1.0, spec_tdp);
            p.advance(1.0);
        }
        let snap = p.snapshot(600.0);
        assert!(snap.devices[0].shed_level >= 1, "GPU at TDP must shed");
        assert!(snap.devices[0].phi < 1.0);
        assert!(p.safety_version() > v0, "band crossings must bump the version");
    }

    #[test]
    fn idle_advance_keeps_version_stable() {
        let mut p = probe(FleetPreset::EdgeBox);
        for _ in 0..100 {
            p.advance(1.0);
        }
        assert_eq!(p.safety_version(), 0, "idle fleet never crosses a band");
        assert!(p.idle_energy_j() > 0.0, "idle draw must be accounted");
        assert_eq!(p.total_energy_j(), p.idle_energy_j());
    }

    #[test]
    fn unloaded_service_uses_the_fastest_device() {
        let p = probe(FleetPreset::EdgeBox);
        let snap = p.snapshot(0.0);
        let best_manual = snap
            .devices
            .iter()
            .map(|d| 32.0 * d.prefill_unit_s + 16.0 * d.step_s)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(p.unloaded_service_s(32, 16), best_manual);
        assert!(best_manual.is_finite() && best_manual > 0.0);
    }

    #[test]
    fn failure_flips_schedulable_and_bumps_the_version() {
        let mut p = probe(FleetPreset::EdgeBox);
        let v0 = p.safety_version();
        p.mark_failed(DevIdx(1), 1.0);
        assert_eq!(p.safety_version(), v0 + 1, "a failure is a safety transition");
        let snap = p.snapshot(1.0);
        assert!(!snap.devices[1].schedulable, "Failed device leaves the schedulable set");
        assert!(snap.devices[0].schedulable);
        assert_eq!(p.health(DevIdx(1)), crate::safety::health::HealthState::Failed);
        p.mark_recovering(DevIdx(1), 2.0);
        assert_eq!(p.safety_version(), v0 + 2, "recovery bumps again (route comes back)");
        assert!(p.snapshot(2.0).devices[1].schedulable, "Recovering is schedulable");
        // Double-failure is idempotent: no spurious version churn.
        p.mark_failed(DevIdx(1), 3.0);
        p.mark_failed(DevIdx(1), 3.5);
        assert_eq!(p.safety_version(), v0 + 3);
    }

    #[test]
    fn record_measured_feeds_the_calibrator() {
        let mut p = probe(FleetPreset::GpuOnly);
        assert!(p.calibration_stats().is_none(), "estimators are opt-in");
        p.enable_calibration();
        // Zero residual: sample counted, no drift event.
        p.record_measured(DevIdx(0), 0.5, 0.5, 10.0, 10.0);
        let stats = p.calibration_stats().unwrap();
        assert_eq!(stats.samples, 1);
        assert_eq!(stats.version, 0);
        // A sustained 3x time residual must fire the drift detector —
        // and because record_measured folds the applied overlay into
        // the nameplate-priced predictions, the bias folds a BOUNDED
        // number of times and then stabilizes (no geometric compounding
        // toward the clamp).
        for _ in 0..200 {
            p.record_measured(DevIdx(0), 0.5, 1.5, 10.0, 30.0);
        }
        let v = p.calibration_stats().unwrap().version;
        assert!((1..=4).contains(&v), "bias must fold a bounded number of times, got {v}");
        // The recovered coefficient models the 3x bias.
        let overlay = p.calibrator().unwrap().overlay(DevIdx(0));
        let scale =
            if overlay.bandwidth_scale != 1.0 { overlay.bandwidth_scale } else { overlay.compute_scale };
        assert!((scale - 1.0 / 3.0).abs() < 0.05, "recovered scale {scale} must approach 1/3");
    }

    #[test]
    fn snapshot_indexes_by_interned_dev() {
        let p = probe(FleetPreset::MultiVendor);
        let snap = p.snapshot(1.0);
        assert_eq!(snap.devices.len(), 5);
        for (i, d) in snap.devices.iter().enumerate() {
            assert_eq!(d.dev, DevIdx(i as u16));
            assert_eq!(snap.device(DevIdx(i as u16)), Some(d));
        }
        assert!(snap.device(DevIdx(9)).is_none());
    }
}
