//! Minimal command-line argument parsing (the offline environment has no
//! `clap`). Supports subcommands, `--flag value`, `--flag=value`, and
//! boolean `--flag` switches, with generated usage text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: a subcommand path plus `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional words before any `--` option (e.g. `experiment t3`).
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(word) = iter.next() {
            if let Some(name) = word.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let value = iter.next().unwrap();
                    args.options.insert(name.to_string(), value);
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(word);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional word (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string option.
    pub fn required(&self, name: &str) -> Result<String> {
        match self.options.get(name) {
            Some(v) => Ok(v.clone()),
            None => bail!("missing required option --{name}"),
        }
    }

    /// Numeric option with a default.
    pub fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.options.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid value {v:?} for --{name}")),
            None => Ok(default),
        }
    }

    /// Boolean switch (present or absent).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["experiment", "t3", "--out", "results", "--seed", "7"]);
        assert_eq!(a.command(), Some("experiment"));
        assert_eq!(a.positional[1], "t3");
        assert_eq!(a.opt("out", "x"), "results");
        assert_eq!(a.num::<u64>("seed", 0).unwrap(), 7);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["serve", "--port=8080"]);
        assert_eq!(a.num::<u16>("port", 0).unwrap(), 8080);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["serve", "--verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b", ""), "v");
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["x"]);
        assert_eq!(a.opt("missing", "d"), "d");
        assert!(a.required("missing").is_err());
        let b = parse(&["x", "--n", "abc"]);
        assert!(b.num::<u32>("n", 1).is_err());
    }
}
