//! Device capability vectors (paper Eq. 10):
//! `d_i = (M_max, B, f, P, n_cores, λ, C_type, T_max, priority)`.

use std::fmt;

/// Stable identifier for a device within a fleet.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub String);

/// Interned, copyable device index within one [`crate::devices::fleet::Fleet`].
///
/// Planner hot paths (greedy assignment, PGSAM annealing, the exact
/// branch-and-bound) compare and store devices millions of times per
/// plan; a `u16` index into the fleet's device table makes those
/// comparisons branch-free and allocation-free, where the heap-backed
/// `DeviceId(String)` would clone and compare byte strings. Resolve back
/// with `Fleet::id_at` / `Fleet::spec_at`; intern with `Fleet::idx_of`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DevIdx(pub u16);

impl DevIdx {
    #[inline]
    pub fn as_usize(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for DevIdx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.0)
    }
}

impl fmt::Display for DeviceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl From<&str> for DeviceId {
    fn from(s: &str) -> Self {
        DeviceId(s.to_string())
    }
}

/// Processor class (paper: CPU / GPU / NPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    Npu,
}

impl DeviceKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DeviceKind::Cpu => "CPU",
            DeviceKind::Gpu => "GPU",
            DeviceKind::Npu => "NPU",
        }
    }
}

/// How a device's software stack dispatches a multi-layer model step:
/// eager frameworks launch kernels per layer (CUDA/SYCL paths), compiled
/// NPU pipelines execute one fused graph per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchGranularity {
    /// Overhead scales with layer count (eager GPU/CPU stacks).
    PerLayer,
    /// One fixed overhead per executed graph (compiled NPU pipelines).
    PerGraph,
}

/// Silicon vendor — the paper stresses multi-vendor orchestration
/// (Intel CPU + Intel NPU + Intel iGPU + NVIDIA dGPU in one box).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Vendor {
    Intel,
    Nvidia,
    Qualcomm,
    Amd,
}

impl Vendor {
    pub fn as_str(&self) -> &'static str {
        match self {
            Vendor::Intel => "Intel",
            Vendor::Nvidia => "NVIDIA",
            Vendor::Qualcomm => "Qualcomm",
            Vendor::Amd => "AMD",
        }
    }
}

/// Full capability vector for one device (paper Eq. 10 + thermal/power
/// parameters for the RC model).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub id: DeviceId,
    pub kind: DeviceKind,
    pub vendor: Vendor,
    /// M_i^max — usable memory (GB).
    pub mem_gb: f64,
    /// B_i — sustained memory bandwidth (GB/s).
    pub bandwidth_gbs: f64,
    /// C_i — peak compute (GFLOP/s, f32-equivalent).
    pub peak_gflops: f64,
    /// f_i — clock (GHz), informational (peak_gflops is authoritative).
    pub freq_ghz: f64,
    pub cores: u32,
    /// P_i — peak board power (W).
    pub tdp_w: f64,
    /// Idle draw (W) while powered but not executing.
    pub idle_w: f64,
    /// λ_i — architecture efficiency multiplier from Formalism 2
    /// (CPU 1.0 baseline; GPU 0.3–0.5; NPU 0.1–0.2).
    pub lambda: f64,
    /// Fraction of TDP drawn by the memory system at full bandwidth
    /// utilization (GPUs pay for HBM even when ALUs idle).
    pub mem_power_frac: f64,
    /// Effective ALU power utilization while executing compute-bound
    /// work: the fraction of the dynamic power range a saturating
    /// kernel actually draws. Nameplate default 0.95 on every preset;
    /// the online calibration subsystem estimates it per device from
    /// measured energy residuals (it was a hardcoded constant inside
    /// the power model before PR 5, invisible to calibration).
    pub compute_util: f64,
    /// T_i^max — junction temperature limit (°C); exceeding risks damage.
    pub t_max_c: f64,
    /// Hardware emergency-throttle trip point (°C), below `t_max_c`.
    pub t_throttle_hw_c: f64,
    /// Ambient temperature (°C).
    pub t_ambient_c: f64,
    /// Thermal resistance junction→ambient (K/W).
    pub r_th_k_per_w: f64,
    /// Thermal RC time constant (s).
    pub tau_th_s: f64,
    /// Scheduling priority (lower = preferred at equal efficiency).
    pub priority: u32,
    /// Fixed per-kernel-launch overhead (µs) — includes the host
    /// framework/driver stack cost per step, which dominates small-model
    /// decode on consumer stacks (CUDA launch+sync ≫ compiled NPU
    /// pipelines). This is the physical mechanism behind the paper's
    /// per-token latency ordering (GPU 1.73 ms vs NPU-led 1.34 ms).
    pub kernel_overhead_us: f64,
    /// Whether `kernel_overhead_us` applies per layer or per graph.
    pub launch_granularity: LaunchGranularity,
    /// Native-precision factor for decode weight streaming (the f(Q) of
    /// Formalism 2 realized in hardware): NPUs execute INT8 natively
    /// (0.25× fp32 bytes), GPUs/CPUs fp16/bf16 paths (0.5×).
    pub decode_bytes_factor: f64,
    /// Host interconnect bandwidth (GB/s) for cross-device transfers.
    pub link_gbs: f64,
}

impl DeviceSpec {
    /// Energy efficiency (paper Eq. 11): peak FLOPs per joule at TDP.
    pub fn flops_per_joule(&self) -> f64 {
        self.peak_gflops * 1e9 / self.tdp_w
    }

    /// Roofline ridge point C/B (FLOPs per byte): tasks with lower
    /// arithmetic intensity are memory-bound on this device.
    pub fn ridge_intensity(&self) -> f64 {
        self.peak_gflops / self.bandwidth_gbs
    }

    /// Bytes movable per joule at peak bandwidth — the figure of merit
    /// for memory-bound decode.
    pub fn bytes_per_joule(&self) -> f64 {
        // Memory-bound execution draws idle + memory-system power.
        let mem_power = self.idle_w + self.mem_power_frac * (self.tdp_w - self.idle_w);
        self.bandwidth_gbs * 1e9 / mem_power
    }

    /// Steady-state junction temperature at a constant power draw.
    pub fn steady_temp_c(&self, power_w: f64) -> f64 {
        self.t_ambient_c + power_w * self.r_th_k_per_w
    }

    /// The paper's edge platform: Intel Core Ultra 9 285HX.
    pub fn intel_cpu() -> DeviceSpec {
        DeviceSpec {
            id: "cpu0".into(),
            kind: DeviceKind::Cpu,
            vendor: Vendor::Intel,
            mem_gb: 127.0,
            bandwidth_gbs: 100.0,
            peak_gflops: 700.0,
            freq_ghz: 2.8,
            cores: 8,
            tdp_w: 45.0,
            idle_w: 6.0,
            lambda: 1.0,
            mem_power_frac: 0.5,
            compute_util: 0.95,
            t_max_c: 100.0,
            t_throttle_hw_c: 95.0,
            t_ambient_c: 25.0,
            r_th_k_per_w: 0.9,
            tau_th_s: 18.0,
            priority: 2,
            kernel_overhead_us: 130.0,
            launch_granularity: LaunchGranularity::PerLayer,
            decode_bytes_factor: 0.5,
            link_gbs: 30.0,
        }
    }

    /// Intel AI Boost NPU (25 W TDP, bandwidth-lean but extremely
    /// power-efficient — the decode workhorse).
    pub fn intel_npu() -> DeviceSpec {
        DeviceSpec {
            id: "npu0".into(),
            kind: DeviceKind::Npu,
            vendor: Vendor::Intel,
            mem_gb: 20.0,
            bandwidth_gbs: 120.0,
            peak_gflops: 10_000.0,
            freq_ghz: 1.4,
            cores: 2,
            tdp_w: 25.0,
            idle_w: 1.0,
            lambda: 0.15,
            mem_power_frac: 0.25,
            compute_util: 0.95,
            t_max_c: 85.0,
            t_throttle_hw_c: 80.0,
            t_ambient_c: 25.0,
            r_th_k_per_w: 1.6,
            tau_th_s: 12.0,
            priority: 0,
            kernel_overhead_us: 300.0,
            launch_granularity: LaunchGranularity::PerGraph,
            decode_bytes_factor: 0.5,
            link_gbs: 25.0,
        }
    }

    /// Intel Graphics iGPU (shared memory, mid efficiency).
    pub fn intel_igpu() -> DeviceSpec {
        DeviceSpec {
            id: "igpu0".into(),
            kind: DeviceKind::Gpu,
            vendor: Vendor::Intel,
            mem_gb: 72.7,
            bandwidth_gbs: 110.0,
            peak_gflops: 6_000.0,
            freq_ghz: 2.0,
            cores: 128,
            tdp_w: 60.0,
            idle_w: 4.0,
            lambda: 0.45,
            mem_power_frac: 0.4,
            compute_util: 0.95,
            t_max_c: 95.0,
            t_throttle_hw_c: 90.0,
            t_ambient_c: 25.0,
            r_th_k_per_w: 0.8,
            tau_th_s: 15.0,
            priority: 1,
            kernel_overhead_us: 250.0,
            launch_granularity: LaunchGranularity::PerLayer,
            decode_bytes_factor: 0.5,
            link_gbs: 40.0,
        }
    }

    /// NVIDIA RTX PRO 5000 Blackwell (compute monster, power hog).
    pub fn nvidia_gpu() -> DeviceSpec {
        DeviceSpec {
            id: "gpu0".into(),
            kind: DeviceKind::Gpu,
            vendor: Vendor::Nvidia,
            mem_gb: 96.2,
            bandwidth_gbs: 900.0,
            peak_gflops: 60_000.0,
            freq_ghz: 2.6,
            cores: 12_000,
            tdp_w: 300.0,
            idle_w: 35.0,
            lambda: 0.4,
            mem_power_frac: 0.75,
            compute_util: 0.95,
            t_max_c: 95.0,
            t_throttle_hw_c: 85.0,
            t_ambient_c: 25.0,
            r_th_k_per_w: 0.213,
            tau_th_s: 25.0,
            priority: 3,
            kernel_overhead_us: 450.0,
            launch_granularity: LaunchGranularity::PerLayer,
            decode_bytes_factor: 0.5,
            link_gbs: 32.0,
        }
    }

    /// A Qualcomm-style NPU preset (future-work hardware in the paper;
    /// used by the robustness ablations).
    pub fn qualcomm_npu() -> DeviceSpec {
        DeviceSpec {
            id: "qnpu0".into(),
            kind: DeviceKind::Npu,
            vendor: Vendor::Qualcomm,
            mem_gb: 16.0,
            bandwidth_gbs: 75.0,
            peak_gflops: 15_000.0,
            freq_ghz: 1.0,
            cores: 4,
            tdp_w: 20.0,
            idle_w: 0.8,
            lambda: 0.12,
            mem_power_frac: 0.25,
            compute_util: 0.95,
            t_max_c: 80.0,
            t_throttle_hw_c: 75.0,
            t_ambient_c: 25.0,
            r_th_k_per_w: 1.8,
            tau_th_s: 10.0,
            priority: 0,
            kernel_overhead_us: 350.0,
            launch_granularity: LaunchGranularity::PerGraph,
            decode_bytes_factor: 0.5,
            link_gbs: 20.0,
        }
    }

    /// Datacenter-class GPU used by the edge-vs-cloud regime analysis
    /// (§5.5): more of everything, including power.
    pub fn cloud_gpu() -> DeviceSpec {
        DeviceSpec {
            id: "cloud-gpu0".into(),
            kind: DeviceKind::Gpu,
            vendor: Vendor::Nvidia,
            mem_gb: 192.0,
            bandwidth_gbs: 3_350.0,
            peak_gflops: 495_000.0,
            freq_ghz: 1.8,
            cores: 16_896,
            tdp_w: 700.0,
            idle_w: 90.0,
            lambda: 0.35,
            mem_power_frac: 0.7,
            compute_util: 0.95,
            t_max_c: 90.0,
            t_throttle_hw_c: 85.0,
            t_ambient_c: 22.0,
            r_th_k_per_w: 0.06,
            tau_th_s: 40.0,
            priority: 5,
            kernel_overhead_us: 200.0,
            launch_granularity: LaunchGranularity::PerGraph,
            decode_bytes_factor: 0.5,
            link_gbs: 64.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npu_is_most_power_efficient_for_memory_bound_work() {
        let npu = DeviceSpec::intel_npu();
        let gpu = DeviceSpec::nvidia_gpu();
        let cpu = DeviceSpec::intel_cpu();
        assert!(npu.bytes_per_joule() > gpu.bytes_per_joule());
        assert!(npu.bytes_per_joule() > cpu.bytes_per_joule());
    }

    #[test]
    fn gpu_has_highest_peak_compute() {
        let gpu = DeviceSpec::nvidia_gpu();
        for other in [DeviceSpec::intel_cpu(), DeviceSpec::intel_npu(), DeviceSpec::intel_igpu()] {
            assert!(gpu.peak_gflops > other.peak_gflops);
        }
    }

    #[test]
    fn ridge_point_orders_devices() {
        // CPU has lowest ridge: it becomes compute-bound earliest.
        let cpu = DeviceSpec::intel_cpu();
        let gpu = DeviceSpec::nvidia_gpu();
        assert!(cpu.ridge_intensity() < gpu.ridge_intensity());
    }

    #[test]
    fn gpu_at_tdp_would_overheat_without_protection() {
        // The thermal-protection experiment (Table 10) needs the GPU to
        // exceed its limit at sustained full power.
        let gpu = DeviceSpec::nvidia_gpu();
        assert!(gpu.steady_temp_c(gpu.tdp_w) > 0.85 * gpu.t_max_c);
    }

    #[test]
    fn flops_per_joule_ranking_follows_the_paper() {
        // Paper Eq. 11 ranking: NPU most efficient, then iGPU/dGPU, CPU last.
        let order = [
            DeviceSpec::intel_npu().flops_per_joule(),
            DeviceSpec::nvidia_gpu().flops_per_joule(),
            DeviceSpec::intel_igpu().flops_per_joule(),
            DeviceSpec::intel_cpu().flops_per_joule(),
        ];
        assert!(order[0] > order[1] && order[1] > order[2] && order[2] > order[3]);
    }
}
