//! Roofline execution model (paper Formalism 5).
//!
//! A task with `flops` and `bytes` on a device with peak compute `C` and
//! bandwidth `B` takes `max(flops/C, bytes/B)` plus a fixed launch
//! overhead. The task is memory-bound iff its arithmetic intensity
//! `I = flops/bytes` is below the device ridge `C/B`.

use super::spec::DeviceSpec;

/// Which inference phase a task belongs to (distinct hardware affinity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Token + position embedding lookup (tiny, bandwidth-flavored).
    Embedding,
    /// Full-prompt attention + MLP: compute-bound, high intensity.
    Prefill,
    /// Autoregressive steps: memory-bound, intensity ≈ 1.
    Decode,
    /// Final projection to vocabulary logits.
    LmHead,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Embedding => "embedding",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::LmHead => "lm_head",
        }
    }
}

/// One schedulable unit of compute.
#[derive(Debug, Clone)]
pub struct Task {
    pub phase: Phase,
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes moved through the memory system.
    pub bytes: f64,
    /// Resident memory needed to run (GB) — weights + caches.
    pub mem_gb: f64,
    /// Number of kernel launches the task decomposes into (decode steps
    /// pay the launch overhead per token).
    pub launches: u64,
}

impl Task {
    /// Bytes actually streamed on `spec`: decode reads weights in the
    /// device's native precision (Formalism 2's f(Q) realized per device).
    pub fn effective_bytes(&self, spec: &DeviceSpec) -> f64 {
        if self.phase == Phase::Decode {
            self.bytes * spec.decode_bytes_factor
        } else {
            self.bytes
        }
    }

    /// Arithmetic intensity in FLOPs/byte (raw, device-independent).
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0.0 {
            return f64::INFINITY;
        }
        self.flops / self.bytes
    }

    /// Is this task memory-bound on `spec` (paper Eq. 7: I < C/B)?
    pub fn memory_bound_on(&self, spec: &DeviceSpec) -> bool {
        self.intensity() < spec.ridge_intensity()
    }

    /// Execution seconds on `spec` at a given throttle factor in (0, 1]
    /// (thermal shedding scales attainable compute *and* bandwidth).
    pub fn seconds_on(&self, spec: &DeviceSpec, throttle: f64) -> f64 {
        let throttle = throttle.clamp(0.05, 1.0);
        let compute_s = self.flops / (spec.peak_gflops * 1e9 * spec.lambda_effective() * throttle);
        let memory_s = self.effective_bytes(spec) / (spec.bandwidth_gbs * 1e9 * throttle);
        let eff_launches = match spec.launch_granularity {
            super::spec::LaunchGranularity::PerLayer => self.launches.max(1),
            super::spec::LaunchGranularity::PerGraph => 1,
        };
        let overhead_s = eff_launches as f64 * spec.kernel_overhead_us * 1e-6;
        compute_s.max(memory_s) + overhead_s
    }

    /// Attained compute utilization in [0, 1] when running on `spec`:
    /// ratio of useful FLOP time to total roofline time.
    pub fn compute_utilization(&self, spec: &DeviceSpec) -> f64 {
        let compute_s = self.flops / (spec.peak_gflops * 1e9 * spec.lambda_effective());
        let total = self.seconds_on(spec, 1.0);
        if total == 0.0 {
            return 0.0;
        }
        (compute_s / total).clamp(0.0, 1.0)
    }

    /// Attained bandwidth utilization in [0, 1].
    pub fn bandwidth_utilization(&self, spec: &DeviceSpec) -> f64 {
        let memory_s = self.effective_bytes(spec) / (spec.bandwidth_gbs * 1e9);
        let total = self.seconds_on(spec, 1.0);
        if total == 0.0 {
            return 0.0;
        }
        (memory_s / total).clamp(0.0, 1.0)
    }

    /// Seconds to move this task's activations across the host link when
    /// it is placed on a different device than its predecessor.
    pub fn transfer_seconds(&self, from: &DeviceSpec, to: &DeviceSpec, bytes: f64) -> f64 {
        let link = from.link_gbs.min(to.link_gbs) * 1e9;
        bytes / link
    }
}

impl DeviceSpec {
    /// Effective fraction of peak compute attainable for transformer
    /// inference. λ in Formalism 2 is an *energy* multiplier; for compute
    /// we model NPUs/GPUs reaching a large fraction of peak on MXU-shaped
    /// matmuls and CPUs being SIMD-limited.
    pub fn lambda_effective(&self) -> f64 {
        match self.kind {
            super::spec::DeviceKind::Cpu => 0.55,
            super::spec::DeviceKind::Gpu => 0.65,
            super::spec::DeviceKind::Npu => 0.70,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::spec::DeviceSpec;

    fn decode_task() -> Task {
        // One decode step of a ~1B model: 2 GFLOPs, 4 GB moved.
        Task { phase: Phase::Decode, flops: 2e9, bytes: 4e9, mem_gb: 4.5, launches: 1 }
    }

    fn prefill_task() -> Task {
        // 512-token prefill of the same model: high intensity.
        Task { phase: Phase::Prefill, flops: 1.0e12, bytes: 4.2e9, mem_gb: 4.5, launches: 1 }
    }

    #[test]
    fn decode_is_memory_bound_everywhere() {
        let t = decode_task();
        for spec in [DeviceSpec::intel_cpu(), DeviceSpec::nvidia_gpu(), DeviceSpec::intel_npu()] {
            assert!(t.memory_bound_on(&spec), "{:?}", spec.id);
        }
    }

    #[test]
    fn prefill_is_compute_bound_on_cpu() {
        let t = prefill_task();
        assert!(!t.memory_bound_on(&DeviceSpec::intel_cpu()));
    }

    #[test]
    fn gpu_fastest_for_prefill() {
        let t = prefill_task();
        let gpu = t.seconds_on(&DeviceSpec::nvidia_gpu(), 1.0);
        let cpu = t.seconds_on(&DeviceSpec::intel_cpu(), 1.0);
        let npu = t.seconds_on(&DeviceSpec::intel_npu(), 1.0);
        assert!(gpu < cpu && gpu < npu);
    }

    #[test]
    fn throttle_slows_execution_proportionally() {
        let t = prefill_task();
        let spec = DeviceSpec::nvidia_gpu();
        let full = t.seconds_on(&spec, 1.0);
        let half = t.seconds_on(&spec, 0.5);
        assert!(half > 1.8 * full && half < 2.3 * full, "full={full} half={half}");
    }

    #[test]
    fn throttle_is_clamped() {
        let t = decode_task();
        let spec = DeviceSpec::intel_npu();
        assert!(t.seconds_on(&spec, 0.0).is_finite());
        assert!(t.seconds_on(&spec, 2.0) >= t.seconds_on(&spec, 1.0) * 0.99);
    }

    #[test]
    fn utilizations_are_complementary() {
        let spec = DeviceSpec::nvidia_gpu();
        let d = decode_task();
        // Memory-bound: bandwidth util high, compute util low.
        assert!(d.bandwidth_utilization(&spec) > 0.5);
        assert!(d.compute_utilization(&spec) < 0.2);
        let p = prefill_task();
        assert!(p.compute_utilization(&spec) > 0.5);
    }

    #[test]
    fn launch_overhead_dominates_tiny_tasks() {
        let spec = DeviceSpec::nvidia_gpu();
        let tiny = Task { phase: Phase::Embedding, flops: 1e3, bytes: 1e3, mem_gb: 0.0, launches: 1 };
        let secs = tiny.seconds_on(&spec, 1.0);
        assert!(secs >= spec.kernel_overhead_us * 1e-6);
    }

    #[test]
    fn zero_bytes_means_infinite_intensity() {
        let t = Task { phase: Phase::LmHead, flops: 1e6, bytes: 0.0, mem_gb: 0.0, launches: 1 };
        assert!(t.intensity().is_infinite());
        assert!(!t.memory_bound_on(&DeviceSpec::intel_cpu()));
    }
}
