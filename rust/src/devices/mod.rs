//! Heterogeneous device substrate.
//!
//! The paper evaluated on a bespoke edge box (Intel Core Ultra 9 285HX +
//! Intel AI Boost NPU + Intel iGPU + NVIDIA RTX PRO 5000). None of that
//! silicon is available here, so this module implements the substitution
//! documented in DESIGN.md §S1: each device is a *roofline machine* with
//! a utilization-dependent power model and an RC thermal model, calibrated
//! against real PJRT executions of the same HLO artifacts on this host.
//!
//! The simulation preserves exactly the properties the paper's results
//! depend on: relative device affinity (compute-bound prefill vs
//! memory-bound decode), power-latency trade-offs, thermal throttling
//! dynamics, and failure/recovery behaviour.

pub mod failure;
pub mod fleet;
pub mod power;
pub mod roofline;
pub mod spec;
pub mod thermal;

pub use failure::{FailureKind, FailurePlan, FailureScenario};
pub use fleet::{Fleet, FleetPreset};
pub use power::PowerModel;
pub use roofline::{Phase, Task};
pub use spec::{DevIdx, DeviceId, DeviceKind, DeviceSpec, Vendor};
pub use thermal::ThermalState;
