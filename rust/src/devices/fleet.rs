//! Device fleets: the paper's edge box plus the homogeneous/cloud
//! configurations the ablations compare against.

use anyhow::{bail, Result};

use super::spec::{DevIdx, DeviceId, DeviceSpec};

/// Named fleet presets used across the experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPreset {
    /// The paper's platform: Intel CPU + Intel NPU + Intel iGPU + NVIDIA GPU.
    EdgeBox,
    /// Homogeneous baselines (Table 3).
    CpuOnly,
    GpuOnly,
    NpuOnly,
    IgpuOnly,
    /// Datacenter regime for the edge-vs-cloud analysis (§5.5).
    Cloud,
    /// Multi-vendor stress preset (adds a Qualcomm NPU).
    MultiVendor,
    /// Fleet-scale stress preset: 25 edge boxes (100 devices) for the
    /// metro-area discrete-event drills. Deliberately NOT in [`all`]:
    /// the experiment rungs and the drill matrix iterate the paper's
    /// presets; metro is opted into by name (`--fleet metro`).
    ///
    /// [`all`]: FleetPreset::all
    Metro,
}

impl FleetPreset {
    pub fn all() -> [FleetPreset; 7] {
        [
            FleetPreset::EdgeBox,
            FleetPreset::CpuOnly,
            FleetPreset::GpuOnly,
            FleetPreset::NpuOnly,
            FleetPreset::IgpuOnly,
            FleetPreset::Cloud,
            FleetPreset::MultiVendor,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FleetPreset::EdgeBox => "edge-box",
            FleetPreset::CpuOnly => "cpu-only",
            FleetPreset::GpuOnly => "gpu-only",
            FleetPreset::NpuOnly => "npu-only",
            FleetPreset::IgpuOnly => "igpu-only",
            FleetPreset::Cloud => "cloud",
            FleetPreset::MultiVendor => "multi-vendor",
            FleetPreset::Metro => "metro",
        }
    }

    pub fn from_str(s: &str) -> Result<FleetPreset> {
        Ok(match s {
            "edge-box" => FleetPreset::EdgeBox,
            "cpu-only" => FleetPreset::CpuOnly,
            "gpu-only" => FleetPreset::GpuOnly,
            "npu-only" => FleetPreset::NpuOnly,
            "igpu-only" => FleetPreset::IgpuOnly,
            "cloud" => FleetPreset::Cloud,
            "multi-vendor" => FleetPreset::MultiVendor,
            "metro" => FleetPreset::Metro,
            other => bail!("unknown fleet preset {other:?}"),
        })
    }
}

/// An ordered collection of devices, with an id→index interning map so
/// `idx_of` resolves without a per-call linear string scan.
#[derive(Debug, Clone)]
pub struct Fleet {
    devices: Vec<DeviceSpec>,
    index: std::collections::BTreeMap<DeviceId, DevIdx>,
}

impl Fleet {
    pub fn new(devices: Vec<DeviceSpec>) -> Result<Self> {
        if devices.is_empty() {
            bail!("fleet must contain at least one device");
        }
        if devices.len() > u16::MAX as usize {
            bail!("fleet exceeds the DevIdx interning range (u16)");
        }
        let index: std::collections::BTreeMap<DeviceId, DevIdx> = devices
            .iter()
            .enumerate()
            .map(|(i, d)| (d.id.clone(), DevIdx(i as u16)))
            .collect();
        if index.len() != devices.len() {
            bail!("duplicate device ids in fleet");
        }
        Ok(Fleet { devices, index })
    }

    pub fn preset(preset: FleetPreset) -> Fleet {
        let devices = match preset {
            FleetPreset::EdgeBox => vec![
                DeviceSpec::intel_cpu(),
                DeviceSpec::intel_npu(),
                DeviceSpec::intel_igpu(),
                DeviceSpec::nvidia_gpu(),
            ],
            FleetPreset::CpuOnly => vec![DeviceSpec::intel_cpu()],
            FleetPreset::GpuOnly => vec![DeviceSpec::nvidia_gpu()],
            FleetPreset::NpuOnly => vec![DeviceSpec::intel_npu()],
            FleetPreset::IgpuOnly => vec![DeviceSpec::intel_igpu()],
            FleetPreset::Cloud => vec![DeviceSpec::cloud_gpu()],
            FleetPreset::MultiVendor => vec![
                DeviceSpec::intel_cpu(),
                DeviceSpec::intel_npu(),
                DeviceSpec::intel_igpu(),
                DeviceSpec::nvidia_gpu(),
                DeviceSpec::qualcomm_npu(),
            ],
            FleetPreset::Metro => (0..25)
                .flat_map(|i| {
                    [
                        ("cpu", DeviceSpec::intel_cpu()),
                        ("npu", DeviceSpec::intel_npu()),
                        ("igpu", DeviceSpec::intel_igpu()),
                        ("gpu", DeviceSpec::nvidia_gpu()),
                    ]
                    .into_iter()
                    .map(move |(prefix, mut spec)| {
                        spec.id = DeviceId(format!("{prefix}{i}"));
                        spec
                    })
                })
                .collect(),
        };
        Fleet::new(devices).expect("presets are valid")
    }

    pub fn devices(&self) -> &[DeviceSpec] {
        &self.devices
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn get(&self, id: &DeviceId) -> Option<&DeviceSpec> {
        self.idx_of(id).map(|idx| self.spec_at(idx))
    }

    /// Intern a device id into its fleet index (the copyable handle the
    /// planner hot paths operate on). An O(log D) map lookup, not a
    /// string scan over the device table.
    pub fn idx_of(&self, id: &DeviceId) -> Option<DevIdx> {
        self.index.get(id).copied()
    }

    /// Resolve an interned index back to its id. Panics on a stale index
    /// from a different fleet that is out of range.
    pub fn id_at(&self, idx: DevIdx) -> &DeviceId {
        &self.devices[idx.as_usize()].id
    }

    /// Resolve an interned index to the full capability vector.
    pub fn spec_at(&self, idx: DevIdx) -> &DeviceSpec {
        &self.devices[idx.as_usize()]
    }

    pub fn total_memory_gb(&self) -> f64 {
        self.devices.iter().map(|d| d.mem_gb).sum()
    }

    pub fn total_tdp_w(&self) -> f64 {
        self.devices.iter().map(|d| d.tdp_w).sum()
    }

    /// Devices sorted by energy efficiency (paper Eq. 11), ties broken by
    /// priority: the preprocessing step of the optimization engine.
    pub fn ranked_by_efficiency(&self) -> Vec<&DeviceSpec> {
        let mut out: Vec<&DeviceSpec> = self.devices.iter().collect();
        out.sort_by(|a, b| {
            b.flops_per_joule()
                .total_cmp(&a.flops_per_joule())
                .then(a.priority.cmp(&b.priority))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_box_is_multi_vendor() {
        let f = Fleet::preset(FleetPreset::EdgeBox);
        assert_eq!(f.len(), 4);
        let vendors: std::collections::HashSet<_> =
            f.devices().iter().map(|d| d.vendor).collect();
        assert!(vendors.len() >= 2, "edge box must span vendors");
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(Fleet::new(vec![]).is_err());
    }

    #[test]
    fn duplicate_ids_rejected() {
        let r = Fleet::new(vec![DeviceSpec::intel_cpu(), DeviceSpec::intel_cpu()]);
        assert!(r.is_err());
    }

    #[test]
    fn ranking_puts_npu_first_on_edge_box() {
        let f = Fleet::preset(FleetPreset::EdgeBox);
        let ranked = f.ranked_by_efficiency();
        assert_eq!(ranked[0].id, "npu0".into());
    }

    #[test]
    fn preset_roundtrip_names() {
        for p in FleetPreset::all() {
            assert_eq!(FleetPreset::from_str(p.as_str()).unwrap(), p);
        }
        assert_eq!(FleetPreset::from_str("metro").unwrap(), FleetPreset::Metro);
        assert!(FleetPreset::from_str("bogus").is_err());
    }

    #[test]
    fn metro_is_fleet_scale_and_opt_in() {
        let f = Fleet::preset(FleetPreset::Metro);
        assert_eq!(f.len(), 100, "25 edge boxes of 4 devices");
        // Unique ids, interning intact at fleet scale.
        assert_eq!(f.idx_of(&"gpu24".into()).map(|i| i.as_usize()), Some(99));
        assert!(f.get(&"cpu0".into()).is_some());
        assert!(f.get(&"cpu25".into()).is_none());
        // The paper-preset matrix stays 7-wide: metro is by-name only.
        assert!(!FleetPreset::all().contains(&FleetPreset::Metro));
    }

    #[test]
    fn lookup_by_id() {
        let f = Fleet::preset(FleetPreset::EdgeBox);
        assert!(f.get(&"npu0".into()).is_some());
        assert!(f.get(&"nope".into()).is_none());
    }

    #[test]
    fn interning_round_trips() {
        let f = Fleet::preset(FleetPreset::MultiVendor);
        for (i, d) in f.devices().iter().enumerate() {
            let idx = f.idx_of(&d.id).unwrap();
            assert_eq!(idx.as_usize(), i);
            assert_eq!(f.id_at(idx), &d.id);
            assert_eq!(f.spec_at(idx).id, d.id);
        }
        assert!(f.idx_of(&"nope".into()).is_none());
    }
}
