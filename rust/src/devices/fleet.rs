//! Device fleets: the paper's edge box plus the homogeneous/cloud
//! configurations the ablations compare against.

use anyhow::{bail, Result};

use super::spec::{DeviceId, DeviceSpec};

/// Named fleet presets used across the experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPreset {
    /// The paper's platform: Intel CPU + Intel NPU + Intel iGPU + NVIDIA GPU.
    EdgeBox,
    /// Homogeneous baselines (Table 3).
    CpuOnly,
    GpuOnly,
    NpuOnly,
    IgpuOnly,
    /// Datacenter regime for the edge-vs-cloud analysis (§5.5).
    Cloud,
    /// Multi-vendor stress preset (adds a Qualcomm NPU).
    MultiVendor,
}

impl FleetPreset {
    pub fn all() -> [FleetPreset; 7] {
        [
            FleetPreset::EdgeBox,
            FleetPreset::CpuOnly,
            FleetPreset::GpuOnly,
            FleetPreset::NpuOnly,
            FleetPreset::IgpuOnly,
            FleetPreset::Cloud,
            FleetPreset::MultiVendor,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FleetPreset::EdgeBox => "edge-box",
            FleetPreset::CpuOnly => "cpu-only",
            FleetPreset::GpuOnly => "gpu-only",
            FleetPreset::NpuOnly => "npu-only",
            FleetPreset::IgpuOnly => "igpu-only",
            FleetPreset::Cloud => "cloud",
            FleetPreset::MultiVendor => "multi-vendor",
        }
    }

    pub fn from_str(s: &str) -> Result<FleetPreset> {
        Ok(match s {
            "edge-box" => FleetPreset::EdgeBox,
            "cpu-only" => FleetPreset::CpuOnly,
            "gpu-only" => FleetPreset::GpuOnly,
            "npu-only" => FleetPreset::NpuOnly,
            "igpu-only" => FleetPreset::IgpuOnly,
            "cloud" => FleetPreset::Cloud,
            "multi-vendor" => FleetPreset::MultiVendor,
            other => bail!("unknown fleet preset {other:?}"),
        })
    }
}

/// An ordered collection of devices.
#[derive(Debug, Clone)]
pub struct Fleet {
    devices: Vec<DeviceSpec>,
}

impl Fleet {
    pub fn new(devices: Vec<DeviceSpec>) -> Result<Self> {
        if devices.is_empty() {
            bail!("fleet must contain at least one device");
        }
        let mut ids: Vec<&str> = devices.iter().map(|d| d.id.0.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != devices.len() {
            bail!("duplicate device ids in fleet");
        }
        Ok(Fleet { devices })
    }

    pub fn preset(preset: FleetPreset) -> Fleet {
        let devices = match preset {
            FleetPreset::EdgeBox => vec![
                DeviceSpec::intel_cpu(),
                DeviceSpec::intel_npu(),
                DeviceSpec::intel_igpu(),
                DeviceSpec::nvidia_gpu(),
            ],
            FleetPreset::CpuOnly => vec![DeviceSpec::intel_cpu()],
            FleetPreset::GpuOnly => vec![DeviceSpec::nvidia_gpu()],
            FleetPreset::NpuOnly => vec![DeviceSpec::intel_npu()],
            FleetPreset::IgpuOnly => vec![DeviceSpec::intel_igpu()],
            FleetPreset::Cloud => vec![DeviceSpec::cloud_gpu()],
            FleetPreset::MultiVendor => vec![
                DeviceSpec::intel_cpu(),
                DeviceSpec::intel_npu(),
                DeviceSpec::intel_igpu(),
                DeviceSpec::nvidia_gpu(),
                DeviceSpec::qualcomm_npu(),
            ],
        };
        Fleet::new(devices).expect("presets are valid")
    }

    pub fn devices(&self) -> &[DeviceSpec] {
        &self.devices
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn get(&self, id: &DeviceId) -> Option<&DeviceSpec> {
        self.devices.iter().find(|d| &d.id == id)
    }

    pub fn total_memory_gb(&self) -> f64 {
        self.devices.iter().map(|d| d.mem_gb).sum()
    }

    pub fn total_tdp_w(&self) -> f64 {
        self.devices.iter().map(|d| d.tdp_w).sum()
    }

    /// Devices sorted by energy efficiency (paper Eq. 11), ties broken by
    /// priority: the preprocessing step of the optimization engine.
    pub fn ranked_by_efficiency(&self) -> Vec<&DeviceSpec> {
        let mut out: Vec<&DeviceSpec> = self.devices.iter().collect();
        out.sort_by(|a, b| {
            b.flops_per_joule()
                .total_cmp(&a.flops_per_joule())
                .then(a.priority.cmp(&b.priority))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_box_is_multi_vendor() {
        let f = Fleet::preset(FleetPreset::EdgeBox);
        assert_eq!(f.len(), 4);
        let vendors: std::collections::HashSet<_> =
            f.devices().iter().map(|d| d.vendor).collect();
        assert!(vendors.len() >= 2, "edge box must span vendors");
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(Fleet::new(vec![]).is_err());
    }

    #[test]
    fn duplicate_ids_rejected() {
        let r = Fleet::new(vec![DeviceSpec::intel_cpu(), DeviceSpec::intel_cpu()]);
        assert!(r.is_err());
    }

    #[test]
    fn ranking_puts_npu_first_on_edge_box() {
        let f = Fleet::preset(FleetPreset::EdgeBox);
        let ranked = f.ranked_by_efficiency();
        assert_eq!(ranked[0].id, "npu0".into());
    }

    #[test]
    fn preset_roundtrip_names() {
        for p in FleetPreset::all() {
            assert_eq!(FleetPreset::from_str(p.as_str()).unwrap(), p);
        }
        assert!(FleetPreset::from_str("bogus").is_err());
    }

    #[test]
    fn lookup_by_id() {
        let f = Fleet::preset(FleetPreset::EdgeBox);
        assert!(f.get(&"npu0".into()).is_some());
        assert!(f.get(&"nope".into()).is_none());
    }
}
