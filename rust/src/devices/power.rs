//! Utilization-dependent power model (paper Formalism 2 substrate).
//!
//! Instantaneous draw while running a task is
//! `P = idle + (tdp − idle) · max(u_compute, mem_frac · u_bandwidth)`:
//! ALU-saturating work pulls toward TDP; memory-bound work pays the
//! memory-system share (large on HBM GPUs, small on NPUs). This is what
//! makes decode-on-NPU the energy winner — the physical mechanism behind
//! the paper's 47–78% energy reductions.

use super::roofline::Task;
use super::spec::DeviceSpec;

/// Computes instantaneous power and integrates energy for one device.
#[derive(Debug, Clone)]
pub struct PowerModel {
    spec: DeviceSpec,
}

impl PowerModel {
    pub fn new(spec: DeviceSpec) -> Self {
        PowerModel { spec }
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    /// Instantaneous draw (W) for `task` on `spec`, borrow-only — the
    /// planner/simulator hot paths call this instead of constructing a
    /// `PowerModel` (which would clone the spec's heap-backed id).
    ///
    /// Phase-saturation model: a memory-bound task keeps the memory
    /// system busy for its whole active phase (draw = idle +
    /// mem_power_frac share of the dynamic range — HBM GPUs pay dearly
    /// here); a compute-bound task drives the ALUs near TDP
    /// (`DeviceSpec::compute_util`, nameplate 0.95 — a per-device
    /// coefficient so online calibration can estimate it, not the
    /// hardcoded constant it used to be).
    pub fn active_power_for(spec: &DeviceSpec, task: &Task) -> f64 {
        let util =
            if task.memory_bound_on(spec) { spec.mem_power_frac } else { spec.compute_util };
        spec.idle_w + (spec.tdp_w - spec.idle_w) * util
    }

    /// Energy (J) to execute `task` on `spec` at a throttle factor,
    /// borrow-only (see [`PowerModel::active_power_for`]).
    pub fn energy_for(spec: &DeviceSpec, task: &Task, throttle: f64) -> f64 {
        Self::active_power_for(spec, task) * task.seconds_on(spec, throttle)
    }

    /// Instantaneous draw (W) while executing `task`.
    pub fn active_power_w(&self, task: &Task) -> f64 {
        Self::active_power_for(&self.spec, task)
    }

    /// Draw while idle but powered.
    pub fn idle_power_w(&self) -> f64 {
        self.spec.idle_w
    }

    /// Energy (J) to execute `task` at a throttle factor.
    pub fn task_energy_j(&self, task: &Task, throttle: f64) -> f64 {
        Self::energy_for(&self.spec, task, throttle)
    }

    /// Utilization efficiency γ_util from Formalism 2: fraction of peak
    /// power actually drawn during this task.
    pub fn gamma_util(&self, task: &Task) -> f64 {
        self.active_power_w(task) / self.spec.tdp_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::roofline::Phase;

    fn decode_task() -> Task {
        Task { phase: Phase::Decode, flops: 2e9, bytes: 4e9, mem_gb: 4.5, launches: 1 }
    }

    fn prefill_task() -> Task {
        Task { phase: Phase::Prefill, flops: 1.0e12, bytes: 4.2e9, mem_gb: 4.5, launches: 1 }
    }

    #[test]
    fn power_bounded_by_idle_and_tdp() {
        for spec in [DeviceSpec::intel_cpu(), DeviceSpec::nvidia_gpu(), DeviceSpec::intel_npu()] {
            let pm = PowerModel::new(spec.clone());
            for task in [decode_task(), prefill_task()] {
                let p = pm.active_power_w(&task);
                assert!(p >= spec.idle_w && p <= spec.tdp_w, "{}: {p}", spec.id);
            }
        }
    }

    #[test]
    fn prefill_draws_more_than_decode_on_gpu() {
        let pm = PowerModel::new(DeviceSpec::nvidia_gpu());
        assert!(pm.active_power_w(&prefill_task()) > pm.active_power_w(&decode_task()));
    }

    #[test]
    fn decode_energy_cheapest_on_npu() {
        // The core physical claim behind heterogeneous energy savings.
        let t = decode_task();
        let npu = PowerModel::new(DeviceSpec::intel_npu()).task_energy_j(&t, 1.0);
        let gpu = PowerModel::new(DeviceSpec::nvidia_gpu()).task_energy_j(&t, 1.0);
        let cpu = PowerModel::new(DeviceSpec::intel_cpu()).task_energy_j(&t, 1.0);
        assert!(npu < gpu, "npu={npu} gpu={gpu}");
        assert!(npu < cpu, "npu={npu} cpu={cpu}");
    }

    #[test]
    fn prefill_energy_on_gpu_beats_cpu() {
        // Compute-bound work: the GPU finishes so much faster that it
        // wins on energy despite the higher draw.
        let t = prefill_task();
        let gpu = PowerModel::new(DeviceSpec::nvidia_gpu()).task_energy_j(&t, 1.0);
        let cpu = PowerModel::new(DeviceSpec::intel_cpu()).task_energy_j(&t, 1.0);
        assert!(gpu < cpu, "gpu={gpu} cpu={cpu}");
    }

    #[test]
    fn throttling_increases_task_energy_mildly() {
        // Throttled execution takes longer at lower effective power —
        // energy grows at most linearly with slowdown.
        let t = prefill_task();
        let pm = PowerModel::new(DeviceSpec::nvidia_gpu());
        let e_full = pm.task_energy_j(&t, 1.0);
        let e_half = pm.task_energy_j(&t, 0.5);
        assert!(e_half > e_full && e_half < 2.5 * e_full);
    }

    #[test]
    fn compute_util_is_a_per_device_coefficient() {
        // Satellite lock (PR 5): the 0.95 saturation constant lives on
        // the spec (nameplate 0.95, bit-exact with the old hardcode) so
        // calibration can estimate it per device.
        let t = prefill_task();
        let mut spec = DeviceSpec::nvidia_gpu();
        assert_eq!(spec.compute_util, 0.95);
        let nameplate = PowerModel::active_power_for(&spec, &t);
        assert_eq!(nameplate, spec.idle_w + (spec.tdp_w - spec.idle_w) * 0.95);
        spec.compute_util = 0.80;
        assert!(PowerModel::active_power_for(&spec, &t) < nameplate);
        // Memory-bound draw is set by mem_power_frac, not compute_util.
        let d = decode_task();
        let mem = PowerModel::active_power_for(&spec, &d);
        spec.compute_util = 0.95;
        assert_eq!(mem, PowerModel::active_power_for(&spec, &d));
    }

    #[test]
    fn gamma_util_in_range() {
        let pm = PowerModel::new(DeviceSpec::nvidia_gpu());
        for task in [decode_task(), prefill_task()] {
            let g = pm.gamma_util(&task);
            assert!((0.0..=1.0).contains(&g));
        }
    }
}
