//! First-order RC thermal model (DESIGN.md §S5).
//!
//! Junction temperature follows
//! `dT/dt = (P·R_th − (T − T_amb)) / τ_th`:
//! steady state `T_amb + P·R_th`, exponential approach with time
//! constant `τ_th`. The hardware itself force-throttles at `T_max`
//! (emergency behaviour the orchestrator's guard is designed to avoid —
//! paper Eq. 8 enforces `T ≤ 0.85·T_max` proactively).

use super::spec::DeviceSpec;

/// Evolving thermal state of one device.
#[derive(Debug, Clone)]
pub struct ThermalState {
    /// Current junction temperature (°C).
    pub(crate) temp_c: f64,
    /// Count of hardware-level throttling events (entered T >= T_max).
    pub(crate) throttle_events: u64,
    /// Whether the device is currently hardware-throttled.
    pub(crate) throttled: bool,
    /// Peak temperature seen (°C).
    pub(crate) peak_c: f64,
}

impl ThermalState {
    pub fn new(spec: &DeviceSpec) -> Self {
        ThermalState {
            temp_c: spec.t_ambient_c,
            throttle_events: 0,
            throttled: false,
            peak_c: spec.t_ambient_c,
        }
    }

    pub fn temp_c(&self) -> f64 {
        self.temp_c
    }

    pub fn peak_c(&self) -> f64 {
        self.peak_c
    }

    pub fn throttle_events(&self) -> u64 {
        self.throttle_events
    }

    pub fn is_throttled(&self) -> bool {
        self.throttled
    }

    /// Advance the RC model by `dt` seconds at constant power `power_w`.
    pub fn step(&mut self, spec: &DeviceSpec, power_w: f64, dt_s: f64) {
        debug_assert!(dt_s >= 0.0);
        let target = spec.t_ambient_c + power_w * spec.r_th_k_per_w;
        // Exact solution of the linear ODE over the interval.
        let alpha = (-dt_s / spec.tau_th_s).exp();
        self.temp_c = target + (self.temp_c - target) * alpha;
        self.peak_c = self.peak_c.max(self.temp_c);

        // Hardware emergency throttling with hysteresis: trips at the
        // silicon's throttle point, releases 10 °C below it (emergency
        // throttling is deliberately sticky).
        if self.temp_c >= spec.t_throttle_hw_c {
            if !self.throttled {
                self.throttled = true;
                self.throttle_events += 1;
            }
        } else if self.throttled && self.temp_c < spec.t_throttle_hw_c - 10.0 {
            self.throttled = false;
        }
    }

    /// Hardware-enforced throughput factor: 1.0 normally, harshly reduced
    /// while emergency-throttled (the unpredictable behaviour the paper's
    /// guard exists to prevent).
    pub fn hardware_throttle_factor(&self) -> f64 {
        if self.throttled {
            0.2
        } else {
            1.0
        }
    }

    /// Fraction of the way to the thermal limit, 0 at ambient, 1 at T_max.
    pub fn headroom_used(&self, spec: &DeviceSpec) -> f64 {
        ((self.temp_c - spec.t_ambient_c) / (spec.t_max_c - spec.t_ambient_c)).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_ambient() {
        let spec = DeviceSpec::nvidia_gpu();
        let t = ThermalState::new(&spec);
        assert_eq!(t.temp_c(), spec.t_ambient_c);
    }

    #[test]
    fn approaches_steady_state() {
        let spec = DeviceSpec::nvidia_gpu();
        let mut t = ThermalState::new(&spec);
        let p = 200.0;
        for _ in 0..10_000 {
            t.step(&spec, p, 0.1);
        }
        let expect = spec.steady_temp_c(p);
        assert!((t.temp_c() - expect).abs() < 0.5, "temp={} expect={expect}", t.temp_c());
    }

    #[test]
    fn cools_when_idle() {
        let spec = DeviceSpec::nvidia_gpu();
        let mut t = ThermalState::new(&spec);
        for _ in 0..1000 {
            t.step(&spec, spec.tdp_w, 0.1);
        }
        let hot = t.temp_c();
        for _ in 0..10_000 {
            t.step(&spec, spec.idle_w, 0.1);
        }
        assert!(t.temp_c() < hot);
        assert!(t.temp_c() < spec.steady_temp_c(spec.idle_w) + 1.0);
    }

    #[test]
    fn sustained_tdp_trips_hardware_throttle() {
        let spec = DeviceSpec::nvidia_gpu();
        let mut t = ThermalState::new(&spec);
        for _ in 0..50_000 {
            t.step(&spec, spec.tdp_w, 0.1);
        }
        assert!(t.throttle_events() >= 1);
        assert!(t.is_throttled());
        assert!(t.hardware_throttle_factor() < 1.0);
    }

    #[test]
    fn hysteresis_releases_below_limit() {
        let spec = DeviceSpec::nvidia_gpu();
        let mut t = ThermalState::new(&spec);
        for _ in 0..50_000 {
            t.step(&spec, spec.tdp_w, 0.1);
        }
        assert!(t.is_throttled());
        for _ in 0..50_000 {
            t.step(&spec, spec.idle_w, 0.1);
        }
        assert!(!t.is_throttled());
        assert_eq!(t.throttle_events(), 1, "cooling must not double-count events");
    }

    #[test]
    fn peak_records_maximum() {
        let spec = DeviceSpec::intel_npu();
        let mut t = ThermalState::new(&spec);
        for _ in 0..5_000 {
            t.step(&spec, spec.tdp_w, 0.1);
        }
        let peak_hot = t.peak_c();
        for _ in 0..5_000 {
            t.step(&spec, spec.idle_w, 0.1);
        }
        assert_eq!(t.peak_c(), peak_hot);
        assert!(t.temp_c() < peak_hot);
    }

    #[test]
    fn headroom_clamps() {
        let spec = DeviceSpec::intel_cpu();
        let mut t = ThermalState::new(&spec);
        assert_eq!(t.headroom_used(&spec), 0.0);
        for _ in 0..100_000 {
            t.step(&spec, spec.tdp_w * 3.0, 0.1); // absurd power
        }
        assert_eq!(t.headroom_used(&spec), 1.0);
    }
}
