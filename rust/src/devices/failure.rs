//! Failure injection (substrate for Table 11 fault-tolerance evaluation).
//!
//! Scenarios are injected into the simulation clock: at `at_s` a device
//! crashes, hangs (stops responding but does not error), or develops an
//! elevated kernel-error rate; optionally it recovers after a delay.

use super::spec::DeviceId;

/// What goes wrong.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureKind {
    /// Device disappears instantly (driver crash). Detected by heartbeat.
    Crash,
    /// Device stops making progress. Detected by timeout (10× expected).
    Hang,
    /// Fraction of kernel launches fail. Detected by error-rate monitor.
    ErrorRate(f64),
}

/// One scheduled failure.
#[derive(Debug, Clone)]
pub struct FailureScenario {
    pub device: DeviceId,
    pub kind: FailureKind,
    /// Virtual time (s) at which the failure manifests.
    pub at_s: f64,
    /// If set, the device becomes recoverable after this many seconds
    /// (driver reset succeeds).
    pub recover_after_s: Option<f64>,
}

/// A set of scheduled failures, queried by the simulation clock.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    scenarios: Vec<FailureScenario>,
}

impl FailurePlan {
    pub fn new(mut scenarios: Vec<FailureScenario>) -> Self {
        scenarios.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FailurePlan { scenarios }
    }

    pub fn none() -> Self {
        FailurePlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    pub fn scenarios(&self) -> &[FailureScenario] {
        &self.scenarios
    }

    /// Scenarios that trigger in the window `(from_s, to_s]`.
    pub fn triggered(&self, from_s: f64, to_s: f64) -> Vec<&FailureScenario> {
        self.scenarios
            .iter()
            .filter(|s| s.at_s > from_s && s.at_s <= to_s)
            .collect()
    }

    /// Is `device` failed at time `t` under this plan (ignoring
    /// orchestrator-driven recovery, which the safety monitor owns)?
    pub fn hard_failed_at(&self, device: &DeviceId, t: f64) -> bool {
        self.scenarios.iter().any(|s| {
            &s.device == device
                && t >= s.at_s
                && s.recover_after_s.map(|r| t < s.at_s + r).unwrap_or(true)
                && matches!(s.kind, FailureKind::Crash | FailureKind::Hang)
        })
    }
}

/// What a schedule entry does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureAction {
    Fail,
    Recover,
}

/// One expanded transition: at `at_s`, `device` fails or recovers.
#[derive(Debug, Clone)]
pub struct FailureEvent {
    pub device: DeviceId,
    pub action: FailureAction,
    /// The originating scenario's kind (detection latency depends on it).
    pub kind: FailureKind,
    pub at_s: f64,
}

/// A [`FailurePlan`] expanded into a time-sorted transition schedule
/// consumed by a cursor.
///
/// The legacy engine rescanned the whole plan every tick and derived
/// each device's state from `clock >= at_s && clock < at_s + recover`;
/// a fail-and-recover that both land inside one wall interval was
/// collapsed into "nothing happened" because the rescan only saw the
/// final state. Expanding each hard scenario into explicit
/// `Fail(at_s)` / `Recover(at_s + r)` events makes every transition
/// fire exactly once, in order, however coarse the interval — and
/// turns the injector into a natural DES component whose only per-tick
/// work is a cursor comparison.
#[derive(Debug, Clone, Default)]
pub struct FailureSchedule {
    events: Vec<FailureEvent>,
    cursor: usize,
}

impl FailureSchedule {
    /// Expand the hard (Crash/Hang) scenarios of `plan`. Soft
    /// error-rate scenarios stay with the detector path and are not
    /// scheduled here. The sort is stable, so events sharing an `at_s`
    /// keep the plan's scenario order.
    pub fn from_plan(plan: &FailurePlan) -> Self {
        let mut events = Vec::new();
        for s in plan.scenarios() {
            if !matches!(s.kind, FailureKind::Crash | FailureKind::Hang) {
                continue;
            }
            events.push(FailureEvent {
                device: s.device.clone(),
                action: FailureAction::Fail,
                kind: s.kind,
                at_s: s.at_s,
            });
            if let Some(r) = s.recover_after_s {
                events.push(FailureEvent {
                    device: s.device.clone(),
                    action: FailureAction::Recover,
                    kind: s.kind,
                    at_s: s.at_s + r,
                });
            }
        }
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FailureSchedule { events, cursor: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Index of the next unapplied event.
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Restore the cursor (snapshot load). Clamped to the schedule.
    pub fn set_cursor(&mut self, cursor: usize) {
        self.cursor = cursor.min(self.events.len());
    }

    /// Time of the next unapplied event, if any.
    pub fn next_at_s(&self) -> Option<f64> {
        self.events.get(self.cursor).map(|e| e.at_s)
    }

    /// Consume and return every event due at or before `clock_s`.
    pub fn take_due(&mut self, clock_s: f64) -> &[FailureEvent] {
        let start = self.cursor;
        while self.cursor < self.events.len() && self.events[self.cursor].at_s <= clock_s {
            self.cursor += 1;
        }
        &self.events[start..self.cursor]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FailurePlan {
        FailurePlan::new(vec![
            FailureScenario {
                device: "npu0".into(),
                kind: FailureKind::Crash,
                at_s: 10.0,
                recover_after_s: Some(5.0),
            },
            FailureScenario {
                device: "gpu0".into(),
                kind: FailureKind::Hang,
                at_s: 20.0,
                recover_after_s: None,
            },
        ])
    }

    #[test]
    fn triggered_window_is_half_open() {
        let p = plan();
        assert_eq!(p.triggered(0.0, 9.9).len(), 0);
        assert_eq!(p.triggered(9.9, 10.0).len(), 1);
        assert_eq!(p.triggered(10.0, 30.0).len(), 1); // only gpu0 at 20
    }

    #[test]
    fn crash_with_recovery_window() {
        let p = plan();
        let npu: DeviceId = "npu0".into();
        assert!(!p.hard_failed_at(&npu, 9.0));
        assert!(p.hard_failed_at(&npu, 12.0));
        assert!(!p.hard_failed_at(&npu, 15.1)); // recovered
    }

    #[test]
    fn hang_without_recovery_is_permanent() {
        let p = plan();
        let gpu: DeviceId = "gpu0".into();
        assert!(p.hard_failed_at(&gpu, 21.0));
        assert!(p.hard_failed_at(&gpu, 10_000.0));
    }

    #[test]
    fn error_rate_is_not_a_hard_failure() {
        let p = FailurePlan::new(vec![FailureScenario {
            device: "gpu0".into(),
            kind: FailureKind::ErrorRate(0.05),
            at_s: 0.0,
            recover_after_s: None,
        }]);
        assert!(!p.hard_failed_at(&"gpu0".into(), 1.0));
    }

    #[test]
    fn schedule_expands_hard_scenarios_in_time_order() {
        let p = FailurePlan::new(vec![
            FailureScenario {
                device: "npu0".into(),
                kind: FailureKind::Crash,
                at_s: 10.0,
                recover_after_s: Some(5.0),
            },
            FailureScenario {
                device: "gpu0".into(),
                kind: FailureKind::ErrorRate(0.05),
                at_s: 1.0,
                recover_after_s: None,
            },
            FailureScenario {
                device: "cpu0".into(),
                kind: FailureKind::Hang,
                at_s: 12.0,
                recover_after_s: None,
            },
        ]);
        let s = FailureSchedule::from_plan(&p);
        // ErrorRate is soft: not scheduled. Crash expands to two events.
        assert_eq!(s.len(), 3);
        let times: Vec<f64> = (0..s.len()).map(|i| {
            let mut probe = s.clone();
            probe.set_cursor(i);
            probe.next_at_s().unwrap()
        }).collect();
        assert_eq!(times, vec![10.0, 12.0, 15.0]);
    }

    #[test]
    fn schedule_ties_keep_scenario_order() {
        let p = FailurePlan::new(vec![
            FailureScenario { device: "a".into(), kind: FailureKind::Crash, at_s: 0.0, recover_after_s: None },
            FailureScenario { device: "b".into(), kind: FailureKind::Crash, at_s: 0.0, recover_after_s: None },
            FailureScenario { device: "c".into(), kind: FailureKind::Crash, at_s: 0.0, recover_after_s: None },
        ]);
        let mut s = FailureSchedule::from_plan(&p);
        let devs: Vec<DeviceId> = s.take_due(0.0).iter().map(|e| e.device.clone()).collect();
        assert_eq!(devs, vec!["a".into(), "b".into(), "c".into()]);
    }

    #[test]
    fn cursor_consumes_each_event_exactly_once() {
        let p = plan(); // npu0 crash@10 recover@15, gpu0 hang@20
        let mut s = FailureSchedule::from_plan(&p);
        assert_eq!(s.next_at_s(), Some(10.0));
        assert!(s.take_due(9.9).is_empty());

        // A coarse interval that jumps clean over fail AND recover
        // still surfaces both transitions, in order.
        let due: Vec<(DeviceId, FailureAction)> = s
            .take_due(16.0)
            .iter()
            .map(|e| (e.device.clone(), e.action))
            .collect();
        assert_eq!(
            due,
            vec![
                ("npu0".into(), FailureAction::Fail),
                ("npu0".into(), FailureAction::Recover),
            ]
        );
        assert_eq!(s.cursor(), 2);
        assert!(s.take_due(16.0).is_empty(), "events fire exactly once");
        assert_eq!(s.take_due(1e9).len(), 1); // gpu0 hang
        assert_eq!(s.next_at_s(), None);
    }

    #[test]
    fn cursor_restore_clamps() {
        let mut s = FailureSchedule::from_plan(&plan());
        s.set_cursor(999);
        assert_eq!(s.cursor(), 3);
        assert!(s.take_due(1e9).is_empty());
    }

    #[test]
    fn scenarios_sorted_by_time() {
        let p = FailurePlan::new(vec![
            FailureScenario { device: "a".into(), kind: FailureKind::Crash, at_s: 5.0, recover_after_s: None },
            FailureScenario { device: "b".into(), kind: FailureKind::Crash, at_s: 1.0, recover_after_s: None },
        ]);
        assert_eq!(p.scenarios()[0].device, "b".into());
    }
}
