//! Failure injection (substrate for Table 11 fault-tolerance evaluation).
//!
//! Scenarios are injected into the simulation clock: at `at_s` a device
//! crashes, hangs (stops responding but does not error), or develops an
//! elevated kernel-error rate; optionally it recovers after a delay.

use super::spec::DeviceId;

/// What goes wrong.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureKind {
    /// Device disappears instantly (driver crash). Detected by heartbeat.
    Crash,
    /// Device stops making progress. Detected by timeout (10× expected).
    Hang,
    /// Fraction of kernel launches fail. Detected by error-rate monitor.
    ErrorRate(f64),
}

/// One scheduled failure.
#[derive(Debug, Clone)]
pub struct FailureScenario {
    pub device: DeviceId,
    pub kind: FailureKind,
    /// Virtual time (s) at which the failure manifests.
    pub at_s: f64,
    /// If set, the device becomes recoverable after this many seconds
    /// (driver reset succeeds).
    pub recover_after_s: Option<f64>,
}

/// A set of scheduled failures, queried by the simulation clock.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    scenarios: Vec<FailureScenario>,
}

impl FailurePlan {
    pub fn new(mut scenarios: Vec<FailureScenario>) -> Self {
        scenarios.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FailurePlan { scenarios }
    }

    pub fn none() -> Self {
        FailurePlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    pub fn scenarios(&self) -> &[FailureScenario] {
        &self.scenarios
    }

    /// Scenarios that trigger in the window `(from_s, to_s]`.
    pub fn triggered(&self, from_s: f64, to_s: f64) -> Vec<&FailureScenario> {
        self.scenarios
            .iter()
            .filter(|s| s.at_s > from_s && s.at_s <= to_s)
            .collect()
    }

    /// Is `device` failed at time `t` under this plan (ignoring
    /// orchestrator-driven recovery, which the safety monitor owns)?
    pub fn hard_failed_at(&self, device: &DeviceId, t: f64) -> bool {
        self.scenarios.iter().any(|s| {
            &s.device == device
                && t >= s.at_s
                && s.recover_after_s.map(|r| t < s.at_s + r).unwrap_or(true)
                && matches!(s.kind, FailureKind::Crash | FailureKind::Hang)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FailurePlan {
        FailurePlan::new(vec![
            FailureScenario {
                device: "npu0".into(),
                kind: FailureKind::Crash,
                at_s: 10.0,
                recover_after_s: Some(5.0),
            },
            FailureScenario {
                device: "gpu0".into(),
                kind: FailureKind::Hang,
                at_s: 20.0,
                recover_after_s: None,
            },
        ])
    }

    #[test]
    fn triggered_window_is_half_open() {
        let p = plan();
        assert_eq!(p.triggered(0.0, 9.9).len(), 0);
        assert_eq!(p.triggered(9.9, 10.0).len(), 1);
        assert_eq!(p.triggered(10.0, 30.0).len(), 1); // only gpu0 at 20
    }

    #[test]
    fn crash_with_recovery_window() {
        let p = plan();
        let npu: DeviceId = "npu0".into();
        assert!(!p.hard_failed_at(&npu, 9.0));
        assert!(p.hard_failed_at(&npu, 12.0));
        assert!(!p.hard_failed_at(&npu, 15.1)); // recovered
    }

    #[test]
    fn hang_without_recovery_is_permanent() {
        let p = plan();
        let gpu: DeviceId = "gpu0".into();
        assert!(p.hard_failed_at(&gpu, 21.0));
        assert!(p.hard_failed_at(&gpu, 10_000.0));
    }

    #[test]
    fn error_rate_is_not_a_hard_failure() {
        let p = FailurePlan::new(vec![FailureScenario {
            device: "gpu0".into(),
            kind: FailureKind::ErrorRate(0.05),
            at_s: 0.0,
            recover_after_s: None,
        }]);
        assert!(!p.hard_failed_at(&"gpu0".into(), 1.0));
    }

    #[test]
    fn scenarios_sorted_by_time() {
        let p = FailurePlan::new(vec![
            FailureScenario { device: "a".into(), kind: FailureKind::Crash, at_s: 5.0, recover_after_s: None },
            FailureScenario { device: "b".into(), kind: FailureKind::Crash, at_s: 1.0, recover_after_s: None },
        ]);
        assert_eq!(p.scenarios()[0].device, "b".into());
    }
}
