//! `qeil replay` — checkpointed runs, crash-recovery drills, and
//! cross-replica desync scans from the command line.
//!
//! Modes (first match wins):
//!   --drill           run the kill-point drill matrix and exit nonzero
//!                     on any digest/report mismatch
//!   --desync          run a calibrated replica against a deliberately
//!                     stale-coefficient one and report the first
//!                     divergence tick + component
//!   --restore FILE    restore a snapshot, replay the log suffix from
//!                     --log FILE, print the final report
//!   (default)         run fresh; with --checkpoint-dir, write periodic
//!                     snapshots and the event log there so a later
//!                     --restore can continue the run
//!
//! `--fuzz-schedule SEED` (decimal or 0x-hex) runs every engine in
//! `ScheduleMode::Fuzzed(SEED)`: same-tick within-stage component
//! dispatch is permuted per tick. Reports must stay bit-identical to
//! the canonical order — a drill under fuzz is an event-ordering drill
//! on top of the crash-recovery one.

use anyhow::{bail, Context, Result};

use crate::calibration::CalibratedSpec;
use crate::cli::Args;
use crate::coordinator::allocation::ModelShape;
use crate::devices::fleet::{Fleet, FleetPreset};
use crate::devices::spec::DevIdx;
use crate::experiments::runner::default_meta;
use crate::json::Json;
use crate::sim::engine::{SimEngine, SimOptions, SimReport};
use crate::sim::ScheduleMode;
use crate::snapshot::desync::{detect_desync, stale_replica};
use crate::snapshot::drill::{drill_preset, DrillOutcome};
use crate::snapshot::replay::{EventLog, ReplaySession};
use crate::snapshot::{restore_engine, snapshot_engine};
use crate::workload::datasets::{Dataset, ModelFamily};
use crate::workload::generator::WorkloadGenerator;

pub fn run(args: &Args) -> Result<()> {
    if args.flag("drill") {
        drill(args)
    } else if args.flag("desync") {
        desync(args)
    } else if args.flag("restore") {
        restore(args)
    } else {
        fresh(args)
    }
}

fn presets_from(args: &Args) -> Result<Vec<FleetPreset>> {
    let name = args.opt("fleet", "edge-box");
    if name == "all" {
        Ok(FleetPreset::all().to_vec())
    } else {
        Ok(vec![FleetPreset::from_str(&name)?])
    }
}

fn workload(args: &Args) -> Result<(Vec<crate::workload::generator::Query>, u32, SimOptions)> {
    let n = args.num("queries", 120usize)?;
    let samples = args.num("samples", 4u32)?;
    let seed = args.num("seed", 0u64)?;
    let gen = WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, seed);
    let mut options = SimOptions { seed, ..SimOptions::default() };
    options.checkpoint_every = Some(args.num("checkpoint-every", 25u64)?);
    let fuzz_spec = args.opt("fuzz-schedule", "");
    if !fuzz_spec.is_empty() {
        options.schedule = ScheduleMode::Fuzzed(parse_seed(&fuzz_spec)?);
    }
    Ok((gen.queries(n), samples, options))
}

/// `--fuzz-schedule` accepts decimal or `0x`-prefixed hex, matching how
/// the pinned fuzz seeds are written in the test suite.
fn parse_seed(spec: &str) -> Result<u64> {
    let spec = spec.trim();
    match spec.strip_prefix("0x").or_else(|| spec.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => spec.parse(),
    }
    .with_context(|| format!("bad --fuzz-schedule seed {spec:?}"))
}

fn shape() -> ModelShape {
    ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2))
}

fn report_json(report: &SimReport) -> Json {
    Json::obj(vec![
        ("coverage", Json::Num(report.coverage)),
        ("total_energy_j", Json::Num(report.total_energy_j)),
        ("mean_latency_s", Json::Num(report.mean_latency_s)),
        ("p99_latency_s", Json::Num(report.p99_latency_s)),
        ("throughput_tps", Json::Num(report.throughput_tps)),
        ("queries", Json::Num(report.queries as f64)),
        ("failures", Json::Num(report.failures as f64)),
        ("recoveries", Json::Num(report.recoveries as f64)),
        ("replans", Json::Num(report.replans as f64)),
        ("planner", Json::Str(report.planner.into())),
        ("state_digest", Json::Str(format!("{:016x}", report.state_digest))),
    ])
}

fn print_report(args: &Args, report: &SimReport) {
    if args.flag("stats-json") {
        println!("{}", report_json(report).to_string());
    } else {
        println!(
            "queries {}  coverage {:.3}  energy {:.1} J  p99 {:.3} s  digest {:016x}",
            report.queries,
            report.coverage,
            report.total_energy_j,
            report.p99_latency_s,
            report.state_digest
        );
    }
}

/// Fresh run; with --checkpoint-dir, persist the event log up front and
/// a snapshot every `checkpoint_every` ticks so a crash at ANY point is
/// recoverable from disk via --restore.
fn fresh(args: &Args) -> Result<()> {
    let (queries, samples, options) = workload(args)?;
    let preset = FleetPreset::from_str(&args.opt("fleet", "edge-box"))?;
    let cadence = options.checkpoint_every.unwrap_or(0);
    let dir = match args.opt("checkpoint-dir", "") {
        d if d.is_empty() => None,
        d => Some(d),
    };

    let log = EventLog::from_queries(&queries, samples);
    if let Some(dir) = &dir {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
        std::fs::write(format!("{dir}/events.json"), log.to_json().to_string())
            .context("writing event log")?;
    }

    let trace_out = args.opt("trace-out", "");
    let mut engine = SimEngine::new(Fleet::preset(preset), shape(), options.clone());
    // Fresh engines on large fleets get the default calibration-refresh
    // clock divider (metro's profiled default). Restores never pass
    // through here, so serialized clock domains always win; Legacy
    // harnesses ignore divider overrides by contract and get none.
    if !matches!(options.schedule, ScheduleMode::Legacy) {
        engine.apply_default_dividers();
    }
    if !trace_out.is_empty() {
        engine.enable_obs();
    }
    let mut session = ReplaySession::new(engine, log)?;
    while session.step() {
        let tick = session.cursor();
        if cadence > 0 && tick % cadence == 0 {
            if let Some(dir) = &dir {
                let doc = snapshot_engine(session.engine());
                std::fs::write(format!("{dir}/snapshot-{tick:08}.json"), doc.to_string())
                    .with_context(|| format!("writing checkpoint at tick {tick}"))?;
            }
        }
    }
    let report = session.run_to_end();
    print_report(args, &report);
    if !trace_out.is_empty() {
        let obs = session.engine().obs();
        std::fs::write(&trace_out, obs.recorder.chrome_trace().to_string())
            .with_context(|| format!("writing trace to {trace_out}"))?;
        eprintln!(
            "trace: {} events in ring ({} recorded) -> {trace_out}",
            obs.recorder.len(),
            obs.recorder.total_recorded()
        );
        eprint!("{}", obs.profiler.render_table());
    }
    Ok(())
}

/// Restore a snapshot and replay the rest of its event log.
fn restore(args: &Args) -> Result<()> {
    let snap_path = args.required("restore")?;
    let log_path = args.required("log")?;
    let snap_text =
        std::fs::read_to_string(&snap_path).with_context(|| format!("reading {snap_path}"))?;
    let log_text =
        std::fs::read_to_string(&log_path).with_context(|| format!("reading {log_path}"))?;
    let mut engine = restore_engine(&Json::parse(&snap_text)?)?;
    // A restored engine always comes back obs-off (the recorder is not
    // snapshot state); re-arm it here if the resumed run wants a trace.
    let trace_out = args.opt("trace-out", "");
    if !trace_out.is_empty() {
        engine.enable_obs();
    }
    let log = EventLog::from_json(&Json::parse(&log_text)?)?;
    let resumed_at = engine.queries_done();
    let mut session = ReplaySession::new(engine, log)?;
    let remaining = session.remaining();
    eprintln!("restored at tick {resumed_at}; replaying {remaining} logged events");
    let report = session.run_to_end();
    print_report(args, &report);
    if !trace_out.is_empty() {
        let obs = session.engine().obs();
        std::fs::write(&trace_out, obs.recorder.chrome_trace().to_string())
            .with_context(|| format!("writing trace to {trace_out}"))?;
        eprintln!(
            "trace: {} events in ring ({} recorded) -> {trace_out}",
            obs.recorder.len(),
            obs.recorder.total_recorded()
        );
    }
    Ok(())
}

fn parse_kill_ticks(spec: &str) -> Result<Vec<u64>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<u64>().with_context(|| format!("bad kill tick {s:?}")))
        .collect()
}

/// Kill-point drill matrix. Exits nonzero on the first mismatch so CI
/// (scripts/drill.sh) can gate on it.
fn drill(args: &Args) -> Result<()> {
    let (queries, samples, options) = workload(args)?;
    let cadence = options.checkpoint_every.unwrap_or(25).max(1);
    let kill_ticks = parse_kill_ticks(&args.opt(
        "kill-ticks",
        &format!("1,{},{}", queries.len() / 2, queries.len().saturating_sub(1)),
    ))?;
    let fuzz = args.num("fuzz", 2usize)?;

    let mut failed = 0usize;
    for preset in presets_from(args)? {
        let outcomes =
            drill_preset(preset, options.clone(), &queries, samples, cadence, &kill_ticks, fuzz)?;
        for o in &outcomes {
            print_outcome(o);
            if !o.passed() {
                failed += 1;
                // A mismatch auto-dumps the reference run's flight
                // recorder: the dispatch trail leading to the state
                // the recovery failed to reproduce.
                if let Some(trace) = &o.trace {
                    eprintln!("{trace}");
                }
            }
        }
    }
    if failed > 0 {
        bail!("{failed} drill(s) FAILED: recovered state diverged from the uninterrupted run");
    }
    println!("all drills passed");
    Ok(())
}

fn print_outcome(o: &DrillOutcome) {
    println!(
        "drill {:12} kill@{:5} restore@{:5} digest {:016x} {}",
        o.preset.as_str(),
        o.kill_tick,
        o.checkpoint_tick,
        o.final_digest,
        if o.passed() { "OK" } else { "MISMATCH" }
    );
}

/// Cross-replica desync scan: calibrated primary vs a replica whose
/// overlay for one device is pinned stale.
fn desync(args: &Args) -> Result<()> {
    let (queries, samples, options) = workload(args)?;
    let preset = FleetPreset::from_str(&args.opt("fleet", "edge-box"))?;
    let compare_every = args.num("compare-every", 1u64)?;
    let dev = DevIdx(args.num("stale-device", 1u16)?);
    let derate = args.num("stale-bandwidth-scale", 0.5f64)?;

    let mut primary = SimEngine::new(Fleet::preset(preset), shape(), options.clone());
    if !matches!(options.schedule, ScheduleMode::Legacy) {
        primary.apply_default_dividers();
    }
    // The primary runs with its recorder armed so the desync trail
    // includes the dispatches leading up to the split, not just the
    // checkpoint comparisons. (The stale replica is cloned AFTER so
    // both replicas still start from identical engine state — obs is
    // outside the digest either way.)
    primary.enable_obs();
    let overlay = CalibratedSpec { bandwidth_scale: derate, ..CalibratedSpec::identity() };
    let replica = stale_replica(&primary, dev, overlay);

    let log = EventLog::from_queries(&queries, samples);
    let report = detect_desync(primary, replica, &log, compare_every)?;
    match report.first_divergence_tick {
        Some(tick) => {
            println!(
                "desync at tick {tick}: diverging components [{}] ({} comparisons)",
                report.components.join(", "),
                report.checkpoints.len()
            );
            // Divergence auto-dumps the recorder trail.
            eprintln!("{}", report.recorder.render_text(48));
        }
        None => println!(
            "replicas stayed in sync across {} comparisons",
            report.checkpoints.len()
        ),
    }
    let trace_out = args.opt("trace-out", "");
    if !trace_out.is_empty() {
        std::fs::write(&trace_out, report.recorder.chrome_trace().to_string())
            .with_context(|| format!("writing trace to {trace_out}"))?;
        eprintln!(
            "trace: {} events in ring ({} recorded) -> {trace_out}",
            report.recorder.len(),
            report.recorder.total_recorded()
        );
    }
    Ok(())
}
