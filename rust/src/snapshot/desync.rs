//! Cross-replica desync detection.
//!
//! Two replicas of one coordinator fed the same event log MUST hold
//! byte-identical state at every checkpoint boundary — that invariant
//! is what makes snapshot-based failover sound (a standby promoted
//! mid-run behaves exactly like the primary it replaced). This module
//! runs two engines in lockstep through a shared log, compares
//! whole-state digests at a configurable cadence, and on the first
//! mismatch reports the tick AND the diverging state components (the
//! serialization is component-grouped so divergence localizes to
//! "calibration", "ledger", … instead of an opaque hash mismatch).
//!
//! The canonical seeded-desync scenario: a replica whose calibration
//! overlay was force-pinned to stale coefficients. Both replicas see
//! identical arrivals, but the stale replica plans against different
//! physics — the detector must name the first divergent tick and
//! attribute it to the calibration/plan components.

use anyhow::Result;

use crate::calibration::CalibratedSpec;
use crate::devices::spec::DevIdx;
use crate::obs::FlightRecorder;
use crate::sim::engine::SimEngine;
use crate::snapshot::replay::{EventLog, ReplaySession};
use crate::snapshot::{component_digests, engine_digest};

/// One digest comparison point.
#[derive(Debug, Clone)]
pub struct CheckpointComparison {
    pub tick: u64,
    pub digest_a: u64,
    pub digest_b: u64,
}

impl CheckpointComparison {
    pub fn matches(&self) -> bool {
        self.digest_a == self.digest_b
    }
}

/// Result of a lockstep desync scan.
#[derive(Debug, Clone)]
pub struct DesyncReport {
    /// First tick where the replicas' state digests differed; `None`
    /// when the replicas stayed identical through the whole log.
    pub first_divergence_tick: Option<u64>,
    /// State components differing AT the first divergent checkpoint
    /// (names from [`crate::snapshot::COMPONENTS`]).
    pub components: Vec<&'static str>,
    /// Every comparison made, in tick order (the last entry is the
    /// end-of-log comparison).
    pub checkpoints: Vec<CheckpointComparison>,
    /// Flight-recorder trail of the scan: one `checkpoint` event per
    /// comparison plus a `divergence` event naming the components at
    /// the split — so `--desync` leaves a trace, not just a verdict.
    /// Absorbs replica A's engine recorder when that replica ran with
    /// obs armed.
    pub recorder: FlightRecorder,
}

impl DesyncReport {
    pub fn in_sync(&self) -> bool {
        self.first_divergence_tick.is_none()
    }
}

/// Run two replicas through one log in lockstep, comparing state
/// digests every `compare_every` ticks (and always at end of log).
/// Stops stepping at the first divergence — once trajectories split,
/// later comparisons measure nothing.
pub fn detect_desync(
    replica_a: SimEngine,
    replica_b: SimEngine,
    log: &EventLog,
    compare_every: u64,
) -> Result<DesyncReport> {
    let mut a = ReplaySession::new(replica_a, log.clone())?;
    let mut b = ReplaySession::new(replica_b, log.clone())?;
    let mut checkpoints = Vec::new();
    let mut recorder = FlightRecorder::with_capacity(crate::obs::DEFAULT_RING_CAPACITY);

    loop {
        let stepped_a = a.step();
        let stepped_b = b.step();
        debug_assert_eq!(stepped_a, stepped_b, "replicas consumed different event counts");
        let done = !stepped_a;
        let tick = a.cursor();
        let at_boundary = compare_every > 0 && tick % compare_every == 0;
        if done || at_boundary {
            let cmp = CheckpointComparison {
                tick,
                digest_a: engine_digest(a.engine()),
                digest_b: engine_digest(b.engine()),
            };
            let diverged = !cmp.matches();
            recorder.record(
                tick,
                "desync",
                "checkpoint",
                "",
                0,
                &[("match", if diverged { 0.0 } else { 1.0 })],
            );
            checkpoints.push(cmp);
            if diverged {
                let da = component_digests(a.engine());
                let db = component_digests(b.engine());
                let components: Vec<&'static str> = da
                    .iter()
                    .zip(db.iter())
                    .filter(|((_, x), (_, y))| x != y)
                    .map(|((name, _), _)| *name)
                    .collect();
                // The divergence event names the split components in
                // its note so the rendered trail is self-contained.
                recorder.record_note(
                    tick,
                    "desync",
                    "divergence",
                    "",
                    0,
                    &[("components", components.len() as f64)],
                    components.join(","),
                );
                // Replica A's own dispatch trail (if it ran obs-armed)
                // gives the events LEADING UP to the split.
                recorder.absorb(&a.engine().obs().recorder);
                return Ok(DesyncReport {
                    first_divergence_tick: Some(tick),
                    components,
                    checkpoints,
                    recorder,
                });
            }
        }
        if done {
            recorder.absorb(&a.engine().obs().recorder);
            return Ok(DesyncReport {
                first_divergence_tick: None,
                components: Vec::new(),
                checkpoints,
                recorder,
            });
        }
    }
}

/// Build a deliberately-stale replica: a clone of `engine` whose
/// calibration overlay for `device` is force-pinned to `overlay`
/// (version-bumped, planning fleet rebuilt) — the "standby that missed
/// the last calibration fold" failure mode the desync probe exists to
/// catch.
pub fn stale_replica(engine: &SimEngine, device: DevIdx, overlay: CalibratedSpec) -> SimEngine {
    let mut replica = engine.clone();
    replica.force_overlay(device, overlay);
    replica
}
