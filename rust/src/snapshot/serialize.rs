//! Bit-exact engine state (de)serialization.
//!
//! Every `f64` is encoded as the 16-hex-digit big-endian bit pattern of
//! its IEEE-754 representation (`f64_bits`), NOT as a decimal literal:
//! the hand-rolled `Json` number writer has an integer fast path that
//! drops the sign of `-0.0`, cannot represent NaN/Inf (the latency
//! recorder's `min_s` starts at `f64::INFINITY`), and decimal
//! round-tripping of 17-significant-digit values is exactly the class
//! of almost-right that a digest check exists to catch. `u64` values
//! ride as plain JSON numbers below 2^53 and as decimal strings above
//! (PCG state uses the full 64-bit range).
//!
//! The engine state serializes as an object of NAMED COMPONENTS
//! (`fleet`, `devices`, `ledger`, `calibration`, …) so the desync
//! detector can digest each component independently and name the first
//! diverging one, not just "something differs".

use std::collections::{BTreeMap, HashMap, VecDeque};

use anyhow::{bail, Context, Result};

use crate::calibration::drift::{DriftPlan, DriftScenario};
use crate::calibration::drift_detector::PageHinkley;
use crate::calibration::rls::RatioRls;
use crate::calibration::{
    CalibratedSpec, CalibrationConfig, DeviceCalibration, FleetCalibrator,
};
use crate::config::{ExecMode, OrchestratorFeatures};
use crate::coordinator::allocation::{LayerCost, ModelShape};
use crate::coordinator::energy_table::ShapeKey;
use crate::coordinator::pgsam::ParetoPoint;
use crate::coordinator::plan_cache::{
    CachedPlan, PlanCache, PlanCacheStats, PlanKey, PlannerKind,
};
use crate::devices::failure::{FailureKind, FailurePlan, FailureScenario};
use crate::devices::fleet::Fleet;
use crate::devices::spec::{DevIdx, DeviceId, DeviceKind, DeviceSpec, LaunchGranularity, Vendor};
use crate::devices::thermal::ThermalState;
use crate::json::Json;
use crate::metrics::energy::EnergyLedger;
use crate::metrics::latency::LatencyRecorder;
use crate::rng::Pcg;
use crate::safety::fault::FaultDetector;
use crate::safety::health::{DeviceHealth, HealthState};
use crate::safety::thermal_guard::{ShedTracker, ThermalGuard};
use crate::scaling::formalisms::LatencyLaw;
use crate::sim::des::{ComponentId, ScheduleMode, Scheduler, Stage};
use crate::sim::engine::{
    CascadeTrail, DesState, ReplanEvent, SimDevice, SimEngine, SimOptions,
};
use crate::workload::datasets::ModelFamily;

// ---------------------------------------------------------------------
// Scalar codecs
// ---------------------------------------------------------------------

/// Encode an `f64` as its exact bit pattern (16 lowercase hex digits).
pub fn f64_bits(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

/// Decode an `f64` from `f64_bits` form.
pub fn f64_from(j: &Json) -> Result<f64> {
    let s = j.as_str().context("f64 bit pattern must be a string")?;
    let bits = u64::from_str_radix(s, 16)
        .with_context(|| format!("bad f64 bit pattern {s:?}"))?;
    Ok(f64::from_bits(bits))
}

/// Encode a `u64`: a plain JSON number when exactly representable,
/// a decimal string above 2^53.
pub fn u64_json(v: u64) -> Json {
    if v < (1u64 << 53) {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

/// Decode a `u64` from either `u64_json` form.
pub fn u64_from(j: &Json) -> Result<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().with_context(|| format!("bad u64 string {s:?}")),
        other => other.as_u64(),
    }
}

fn opt_f64(v: Option<f64>) -> Json {
    match v {
        Some(x) => f64_bits(x),
        None => Json::Null,
    }
}

fn opt_f64_from(j: &Json) -> Result<Option<f64>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(f64_from(other)?)),
    }
}

fn f64_field(obj: &Json, key: &str) -> Result<f64> {
    f64_from(obj.field(key)?).with_context(|| format!("field {key:?}"))
}

fn u64_field(obj: &Json, key: &str) -> Result<u64> {
    u64_from(obj.field(key)?).with_context(|| format!("field {key:?}"))
}

// ---------------------------------------------------------------------
// Leaf codecs
// ---------------------------------------------------------------------

fn spec_json(s: &DeviceSpec) -> Json {
    Json::obj(vec![
        ("id", Json::Str(s.id.0.clone())),
        ("kind", Json::Str(s.kind.as_str().into())),
        ("vendor", Json::Str(s.vendor.as_str().into())),
        ("mem_gb", f64_bits(s.mem_gb)),
        ("bandwidth_gbs", f64_bits(s.bandwidth_gbs)),
        ("peak_gflops", f64_bits(s.peak_gflops)),
        ("freq_ghz", f64_bits(s.freq_ghz)),
        ("cores", Json::Num(s.cores as f64)),
        ("tdp_w", f64_bits(s.tdp_w)),
        ("idle_w", f64_bits(s.idle_w)),
        ("lambda", f64_bits(s.lambda)),
        ("mem_power_frac", f64_bits(s.mem_power_frac)),
        ("compute_util", f64_bits(s.compute_util)),
        ("t_max_c", f64_bits(s.t_max_c)),
        ("t_throttle_hw_c", f64_bits(s.t_throttle_hw_c)),
        ("t_ambient_c", f64_bits(s.t_ambient_c)),
        ("r_th_k_per_w", f64_bits(s.r_th_k_per_w)),
        ("tau_th_s", f64_bits(s.tau_th_s)),
        ("priority", Json::Num(s.priority as f64)),
        ("kernel_overhead_us", f64_bits(s.kernel_overhead_us)),
        (
            "launch_granularity",
            Json::Str(
                match s.launch_granularity {
                    LaunchGranularity::PerLayer => "per-layer",
                    LaunchGranularity::PerGraph => "per-graph",
                }
                .into(),
            ),
        ),
        ("decode_bytes_factor", f64_bits(s.decode_bytes_factor)),
        ("link_gbs", f64_bits(s.link_gbs)),
    ])
}

fn spec_from(j: &Json) -> Result<DeviceSpec> {
    let kind = match j.str_field("kind")? {
        "CPU" => DeviceKind::Cpu,
        "GPU" => DeviceKind::Gpu,
        "NPU" => DeviceKind::Npu,
        other => bail!("unknown device kind {other:?}"),
    };
    let vendor = match j.str_field("vendor")? {
        "Intel" => Vendor::Intel,
        "NVIDIA" => Vendor::Nvidia,
        "Qualcomm" => Vendor::Qualcomm,
        "AMD" => Vendor::Amd,
        other => bail!("unknown vendor {other:?}"),
    };
    let launch_granularity = match j.str_field("launch_granularity")? {
        "per-layer" => LaunchGranularity::PerLayer,
        "per-graph" => LaunchGranularity::PerGraph,
        other => bail!("unknown launch granularity {other:?}"),
    };
    Ok(DeviceSpec {
        id: DeviceId(j.str_field("id")?.to_string()),
        kind,
        vendor,
        mem_gb: f64_field(j, "mem_gb")?,
        bandwidth_gbs: f64_field(j, "bandwidth_gbs")?,
        peak_gflops: f64_field(j, "peak_gflops")?,
        freq_ghz: f64_field(j, "freq_ghz")?,
        cores: j.u64_field("cores")? as u32,
        tdp_w: f64_field(j, "tdp_w")?,
        idle_w: f64_field(j, "idle_w")?,
        lambda: f64_field(j, "lambda")?,
        mem_power_frac: f64_field(j, "mem_power_frac")?,
        compute_util: f64_field(j, "compute_util")?,
        t_max_c: f64_field(j, "t_max_c")?,
        t_throttle_hw_c: f64_field(j, "t_throttle_hw_c")?,
        t_ambient_c: f64_field(j, "t_ambient_c")?,
        r_th_k_per_w: f64_field(j, "r_th_k_per_w")?,
        tau_th_s: f64_field(j, "tau_th_s")?,
        priority: j.u64_field("priority")? as u32,
        kernel_overhead_us: f64_field(j, "kernel_overhead_us")?,
        launch_granularity,
        decode_bytes_factor: f64_field(j, "decode_bytes_factor")?,
        link_gbs: f64_field(j, "link_gbs")?,
    })
}

fn fleet_json(fleet: &Fleet) -> Json {
    Json::arr(fleet.devices().iter().map(spec_json).collect())
}

fn fleet_from(j: &Json) -> Result<Fleet> {
    let specs = j.as_arr()?.iter().map(spec_from).collect::<Result<Vec<_>>>()?;
    Fleet::new(specs)
}

fn layer_cost_json(c: &LayerCost) -> Json {
    Json::obj(vec![
        ("flops", f64_bits(c.flops)),
        ("bytes", f64_bits(c.bytes)),
        ("mem_gb", f64_bits(c.mem_gb)),
    ])
}

fn layer_cost_from(j: &Json) -> Result<LayerCost> {
    Ok(LayerCost {
        flops: f64_field(j, "flops")?,
        bytes: f64_field(j, "bytes")?,
        mem_gb: f64_field(j, "mem_gb")?,
    })
}

fn shape_json(s: &ModelShape) -> Json {
    Json::obj(vec![
        ("family", Json::Str(s.family.variant().into())),
        ("n_layers", Json::Num(s.n_layers as f64)),
        ("embedding", layer_cost_json(&s.embedding)),
        ("per_layer", layer_cost_json(&s.per_layer)),
        ("lm_head", layer_cost_json(&s.lm_head)),
        ("boundary_bytes", f64_bits(s.boundary_bytes)),
    ])
}

fn shape_from(j: &Json) -> Result<ModelShape> {
    Ok(ModelShape {
        family: ModelFamily::from_str(j.str_field("family")?)?,
        n_layers: j.usize_field("n_layers")?,
        embedding: layer_cost_from(j.field("embedding")?)?,
        per_layer: layer_cost_from(j.field("per_layer")?)?,
        lm_head: layer_cost_from(j.field("lm_head")?)?,
        boundary_bytes: f64_field(j, "boundary_bytes")?,
    })
}

fn features_json(f: &OrchestratorFeatures) -> Json {
    Json::obj(vec![
        ("device_ranking", Json::Bool(f.device_ranking)),
        ("prefill_decode_split", Json::Bool(f.prefill_decode_split)),
        ("greedy_layer_assignment", Json::Bool(f.greedy_layer_assignment)),
        ("pgsam_planner", Json::Bool(f.pgsam_planner)),
        ("adaptive_sample_budget", Json::Bool(f.adaptive_sample_budget)),
        ("safety", Json::Bool(f.safety)),
        ("selection_cascade", Json::Bool(f.selection_cascade)),
        ("plan_cache", Json::Bool(f.plan_cache)),
        ("calibration", Json::Bool(f.calibration)),
    ])
}

fn features_from(j: &Json) -> Result<OrchestratorFeatures> {
    Ok(OrchestratorFeatures {
        device_ranking: j.field("device_ranking")?.as_bool()?,
        prefill_decode_split: j.field("prefill_decode_split")?.as_bool()?,
        greedy_layer_assignment: j.field("greedy_layer_assignment")?.as_bool()?,
        pgsam_planner: j.field("pgsam_planner")?.as_bool()?,
        adaptive_sample_budget: j.field("adaptive_sample_budget")?.as_bool()?,
        safety: j.field("safety")?.as_bool()?,
        selection_cascade: j.field("selection_cascade")?.as_bool()?,
        plan_cache: j.field("plan_cache")?.as_bool()?,
        calibration: j.field("calibration")?.as_bool()?,
    })
}

fn failure_kind_json(k: &FailureKind) -> Json {
    match k {
        FailureKind::Crash => Json::Str("crash".into()),
        FailureKind::Hang => Json::Str("hang".into()),
        FailureKind::ErrorRate(r) => Json::obj(vec![("error_rate", f64_bits(*r))]),
    }
}

fn failure_kind_from(j: &Json) -> Result<FailureKind> {
    match j {
        Json::Str(s) => match s.as_str() {
            "crash" => Ok(FailureKind::Crash),
            "hang" => Ok(FailureKind::Hang),
            other => bail!("unknown failure kind {other:?}"),
        },
        Json::Obj(_) => Ok(FailureKind::ErrorRate(f64_field(j, "error_rate")?)),
        _ => bail!("failure kind must be a string or object"),
    }
}

fn failure_plan_json(p: &FailurePlan) -> Json {
    Json::arr(
        p.scenarios()
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("device", Json::Str(s.device.0.clone())),
                    ("kind", failure_kind_json(&s.kind)),
                    ("at_s", f64_bits(s.at_s)),
                    ("recover_after_s", opt_f64(s.recover_after_s)),
                ])
            })
            .collect(),
    )
}

fn failure_plan_from(j: &Json) -> Result<FailurePlan> {
    let scenarios = j
        .as_arr()?
        .iter()
        .map(|s| {
            Ok(FailureScenario {
                device: DeviceId(s.str_field("device")?.to_string()),
                kind: failure_kind_from(s.field("kind")?)?,
                at_s: f64_field(s, "at_s")?,
                recover_after_s: opt_f64_from(s.field("recover_after_s")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    // `new` re-sorts by at_s; the serialized order already IS that sort
    // (it came from a constructed plan), so this is a stable identity.
    Ok(FailurePlan::new(scenarios))
}

fn drift_plan_json(p: &DriftPlan) -> Json {
    Json::arr(
        p.scenarios()
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("device", Json::Str(s.device.0.clone())),
                    ("at_s", f64_bits(s.at_s)),
                    ("bandwidth_factor", f64_bits(s.bandwidth_factor)),
                    ("compute_factor", f64_bits(s.compute_factor)),
                    ("idle_factor", f64_bits(s.idle_factor)),
                    ("noise_rel", f64_bits(s.noise_rel)),
                ])
            })
            .collect(),
    )
}

fn drift_plan_from(j: &Json) -> Result<DriftPlan> {
    let scenarios = j
        .as_arr()?
        .iter()
        .map(|s| {
            Ok(DriftScenario {
                device: DeviceId(s.str_field("device")?.to_string()),
                at_s: f64_field(s, "at_s")?,
                bandwidth_factor: f64_field(s, "bandwidth_factor")?,
                compute_factor: f64_field(s, "compute_factor")?,
                idle_factor: f64_field(s, "idle_factor")?,
                noise_rel: f64_field(s, "noise_rel")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(DriftPlan::new(scenarios))
}

fn options_json(o: &SimOptions) -> Json {
    Json::obj(vec![
        ("mode", Json::Str(o.mode.as_str().into())),
        ("features", features_json(&o.features)),
        (
            "guard",
            Json::obj(vec![
                ("theta", f64_bits(o.guard.theta)),
                ("fast_monitor_at", f64_bits(o.guard.fast_monitor_at)),
                ("slow_period_s", f64_bits(o.guard.slow_period_s)),
                ("fast_period_s", f64_bits(o.guard.fast_period_s)),
            ]),
        ),
        ("failure_plan", failure_plan_json(&o.failure_plan)),
        ("drift_plan", drift_plan_json(&o.drift_plan)),
        ("max_decode_devices", Json::Num(o.max_decode_devices as f64)),
        (
            "pin_device",
            match &o.pin_device {
                Some(d) => Json::Str(d.0.clone()),
                None => Json::Null,
            },
        ),
        ("latency_sla_s", opt_f64(o.latency_sla_s)),
        ("energy_budget_j", opt_f64(o.energy_budget_j)),
        ("sla_sample_multiple", opt_f64(o.sla_sample_multiple)),
        (
            "checkpoint_every",
            match o.checkpoint_every {
                Some(n) => u64_json(n),
                None => Json::Null,
            },
        ),
        ("seed", u64_json(o.seed)),
    ])
}

fn options_from(j: &Json) -> Result<SimOptions> {
    let guard = j.field("guard")?;
    Ok(SimOptions {
        mode: ExecMode::from_str(j.str_field("mode")?)?,
        features: features_from(j.field("features")?)?,
        guard: ThermalGuard {
            theta: f64_field(guard, "theta")?,
            fast_monitor_at: f64_field(guard, "fast_monitor_at")?,
            slow_period_s: f64_field(guard, "slow_period_s")?,
            fast_period_s: f64_field(guard, "fast_period_s")?,
        },
        failure_plan: failure_plan_from(j.field("failure_plan")?)?,
        drift_plan: drift_plan_from(j.field("drift_plan")?)?,
        max_decode_devices: j.usize_field("max_decode_devices")?,
        pin_device: match j.field("pin_device")? {
            Json::Null => None,
            other => Some(DeviceId(other.as_str()?.to_string())),
        },
        latency_sla_s: opt_f64_from(j.field("latency_sla_s")?)?,
        energy_budget_j: opt_f64_from(j.field("energy_budget_j")?)?,
        sla_sample_multiple: opt_f64_from(j.field("sla_sample_multiple")?)?,
        checkpoint_every: match j.field("checkpoint_every")? {
            Json::Null => None,
            other => Some(u64_from(other)?),
        },
        // Harness state, deliberately absent from the document (like
        // `checkpoint_every`'s digest exclusion): the restoring harness
        // picks the dispatch mode; all modes are digest-equivalent.
        schedule: ScheduleMode::default(),
        seed: u64_field(j, "seed")?,
    })
}

fn device_json(id: &DeviceId, d: &SimDevice) -> Json {
    Json::obj(vec![
        ("id", Json::Str(id.0.clone())),
        ("spec", spec_json(&d.spec)),
        (
            "thermal",
            Json::obj(vec![
                ("temp_c", f64_bits(d.thermal.temp_c)),
                ("throttle_events", u64_json(d.thermal.throttle_events)),
                ("throttled", Json::Bool(d.thermal.throttled)),
                ("peak_c", f64_bits(d.thermal.peak_c)),
            ]),
        ),
        (
            "health",
            Json::obj(vec![
                (
                    "state",
                    Json::Str(
                        match d.health.state() {
                            HealthState::Healthy => "healthy",
                            HealthState::Degraded => "degraded",
                            HealthState::Failed => "failed",
                            HealthState::Recovering => "recovering",
                        }
                        .into(),
                    ),
                ),
                ("since_s", f64_bits(d.health.since_s)),
                ("recovery_successes", Json::Num(d.health.recovery_successes as f64)),
                ("failures_total", u64_json(d.health.failures_total)),
                ("version", u64_json(d.health.version)),
            ]),
        ),
        (
            "detector",
            Json::obj(vec![
                (
                    "window",
                    Json::arr(d.detector.window.iter().map(|&ok| Json::Bool(ok)).collect()),
                ),
                ("last_heartbeat_s", f64_bits(d.detector.last_heartbeat_s)),
            ]),
        ),
        (
            "shed",
            Json::obj(vec![
                ("level", Json::Num(d.shed.level as f64)),
                ("version", u64_json(d.shed.version)),
            ]),
        ),
        ("busy_s", f64_bits(d.busy_s)),
        ("window_energy_j", f64_bits(d.window_energy_j)),
        ("window_busy_s", f64_bits(d.window_busy_s)),
    ])
}

fn device_from(j: &Json) -> Result<(DeviceId, SimDevice)> {
    let id = DeviceId(j.str_field("id")?.to_string());
    let spec = spec_from(j.field("spec")?)?;

    let t = j.field("thermal")?;
    let mut thermal = ThermalState::new(&spec);
    thermal.temp_c = f64_field(t, "temp_c")?;
    thermal.throttle_events = u64_field(t, "throttle_events")?;
    thermal.throttled = t.field("throttled")?.as_bool()?;
    thermal.peak_c = f64_field(t, "peak_c")?;

    let h = j.field("health")?;
    let mut health = DeviceHealth::new(id.clone());
    health.state = match h.str_field("state")? {
        "healthy" => HealthState::Healthy,
        "degraded" => HealthState::Degraded,
        "failed" => HealthState::Failed,
        "recovering" => HealthState::Recovering,
        other => bail!("unknown health state {other:?}"),
    };
    health.since_s = f64_field(h, "since_s")?;
    health.recovery_successes = h.u64_field("recovery_successes")? as u32;
    health.failures_total = u64_field(h, "failures_total")?;
    health.version = u64_field(h, "version")?;

    let det = j.field("detector")?;
    let mut detector = FaultDetector::new(id.clone());
    detector.window = det
        .field("window")?
        .as_arr()?
        .iter()
        .map(|b| b.as_bool())
        .collect::<Result<VecDeque<bool>>>()?;
    detector.last_heartbeat_s = f64_field(det, "last_heartbeat_s")?;

    let sh = j.field("shed")?;
    let mut shed = ShedTracker::default();
    shed.level = sh.u64_field("level")? as u8;
    shed.version = u64_field(sh, "version")?;

    Ok((
        id,
        SimDevice {
            spec,
            thermal,
            health,
            detector,
            shed,
            busy_s: f64_field(j, "busy_s")?,
            window_energy_j: f64_field(j, "window_energy_j")?,
            window_busy_s: f64_field(j, "window_busy_s")?,
        },
    ))
}

fn ledger_json(l: &EnergyLedger) -> Json {
    Json::obj(vec![
        (
            "per_device",
            Json::Obj(
                l.per_device.iter().map(|(id, &j)| (id.0.clone(), f64_bits(j))).collect(),
            ),
        ),
        (
            "per_phase",
            Json::Obj(
                l.per_phase.iter().map(|(&k, &j)| (k.to_string(), f64_bits(j))).collect(),
            ),
        ),
        ("idle_j", f64_bits(l.idle_j)),
        ("total_j", f64_bits(l.total_j)),
        ("busy_seconds", f64_bits(l.busy_seconds)),
        ("wall_seconds", f64_bits(l.wall_seconds)),
    ])
}

fn ledger_from(j: &Json) -> Result<EnergyLedger> {
    let per_device = j
        .field("per_device")?
        .as_obj()?
        .iter()
        .map(|(k, v)| Ok((DeviceId(k.clone()), f64_from(v)?)))
        .collect::<Result<BTreeMap<DeviceId, f64>>>()?;
    // Phase keys are `&'static str` in the ledger; re-intern by matching
    // the known literals (the ledger only ever inserts these).
    let per_phase = j
        .field("per_phase")?
        .as_obj()?
        .iter()
        .map(|(k, v)| {
            let key: &'static str = match k.as_str() {
                "embedding" => "embedding",
                "prefill" => "prefill",
                "decode" => "decode",
                "lm_head" => "lm_head",
                "overhead" => "overhead",
                other => bail!("unknown ledger phase {other:?}"),
            };
            Ok((key, f64_from(v)?))
        })
        .collect::<Result<BTreeMap<&'static str, f64>>>()?;
    Ok(EnergyLedger {
        per_device,
        per_phase,
        idle_j: f64_field(j, "idle_j")?,
        total_j: f64_field(j, "total_j")?,
        busy_seconds: f64_field(j, "busy_seconds")?,
        wall_seconds: f64_field(j, "wall_seconds")?,
    })
}

fn latencies_json(l: &LatencyRecorder) -> Json {
    // Sparse bucket encoding: [index, count] pairs for non-zero buckets
    // (2048 mostly-zero buckets would dominate the snapshot otherwise).
    let buckets: Vec<Json> = l
        .buckets
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(i, &c)| Json::arr(vec![Json::Num(i as f64), u64_json(c)]))
        .collect();
    Json::obj(vec![
        ("buckets", Json::arr(buckets)),
        ("count", u64_json(l.count)),
        ("sum_s", f64_bits(l.sum_s)),
        ("sum_sq_s", f64_bits(l.sum_sq_s)),
        ("min_s", f64_bits(l.min_s)),
        ("max_s", f64_bits(l.max_s)),
    ])
}

fn latencies_from(j: &Json) -> Result<LatencyRecorder> {
    let mut rec = LatencyRecorder::new();
    for pair in j.field("buckets")?.as_arr()? {
        let pair = pair.as_arr()?;
        if pair.len() != 2 {
            bail!("latency bucket pair must be [index, count]");
        }
        let idx = pair[0].as_usize()?;
        if idx >= rec.buckets.len() {
            bail!("latency bucket index {idx} out of range");
        }
        rec.buckets[idx] = u64_from(&pair[1])?;
    }
    rec.count = u64_field(j, "count")?;
    rec.sum_s = f64_field(j, "sum_s")?;
    rec.sum_sq_s = f64_field(j, "sum_sq_s")?;
    rec.min_s = f64_field(j, "min_s")?;
    rec.max_s = f64_field(j, "max_s")?;
    Ok(rec)
}

fn cascade_json(c: &CascadeTrail) -> Json {
    Json::obj(vec![
        ("samples_budgeted", u64_json(c.samples_budgeted)),
        ("samples_drawn", u64_json(c.samples_drawn)),
        ("energy_saved_j", f64_bits(c.energy_saved_j)),
        ("success_stops", u64_json(c.success_stops)),
        ("futility_stops", u64_json(c.futility_stops)),
        ("exhausted_stops", u64_json(c.exhausted_stops)),
    ])
}

fn cascade_from(j: &Json) -> Result<CascadeTrail> {
    Ok(CascadeTrail {
        samples_budgeted: u64_field(j, "samples_budgeted")?,
        samples_drawn: u64_field(j, "samples_drawn")?,
        energy_saved_j: f64_field(j, "energy_saved_j")?,
        success_stops: u64_field(j, "success_stops")?,
        futility_stops: u64_field(j, "futility_stops")?,
        exhausted_stops: u64_field(j, "exhausted_stops")?,
    })
}

fn plan_chain_json(plan: &[DevIdx]) -> Json {
    Json::arr(plan.iter().map(|d| Json::Num(d.0 as f64)).collect())
}

fn plan_chain_from(j: &Json) -> Result<Vec<DevIdx>> {
    j.as_arr()?.iter().map(|v| Ok(DevIdx(v.as_u64()? as u16))).collect()
}

fn pareto_json(p: &ParetoPoint) -> Json {
    Json::obj(vec![
        ("energy_j", f64_bits(p.energy_j)),
        ("latency_s", f64_bits(p.latency_s)),
        ("underutil", f64_bits(p.underutil)),
        ("plan", plan_chain_json(&p.plan)),
    ])
}

fn pareto_from(j: &Json) -> Result<ParetoPoint> {
    Ok(ParetoPoint {
        energy_j: f64_field(j, "energy_j")?,
        latency_s: f64_field(j, "latency_s")?,
        underutil: f64_field(j, "underutil")?,
        plan: plan_chain_from(j.field("plan")?)?,
    })
}

fn planner_kind_json(k: PlannerKind) -> Json {
    Json::Str(k.as_str().into())
}

fn planner_kind_from(j: &Json) -> Result<PlannerKind> {
    match j.as_str()? {
        "greedy" => Ok(PlannerKind::Greedy),
        "pgsam" => Ok(PlannerKind::Pgsam),
        other => bail!("unknown planner kind {other:?}"),
    }
}

fn plan_cache_json(c: &PlanCache) -> Json {
    // Entries in INSERTION order (the `order` vec), not map order: the
    // FIFO eviction / warm-hint order is behavioral state. `PlanKey`
    // serializes WITHOUT its shape component — the engine has exactly
    // one shape, reattached on restore (`ShapeKey` is private-field and
    // reconstructible from the shape, so persisting it would only add
    // a second copy that could drift from the real one).
    let entries: Vec<Json> = c
        .order
        .iter()
        .map(|key| {
            let entry = &c.entries[key];
            Json::obj(vec![
                (
                    "key",
                    Json::obj(vec![
                        (
                            "usable",
                            Json::arr(key.usable.iter().map(|&b| Json::Bool(b)).collect()),
                        ),
                        ("calibration", u64_json(key.calibration)),
                        ("planner", planner_kind_json(key.planner)),
                        ("seed", u64_json(key.seed)),
                    ]),
                ),
                ("plan", plan_chain_json(&entry.plan)),
                ("energy_j", f64_bits(entry.energy_j)),
                ("archive", Json::arr(entry.archive.iter().map(pareto_json).collect())),
            ])
        })
        .collect();
    let s = c.stats;
    Json::obj(vec![
        ("cap", Json::Num(c.cap as f64)),
        (
            "stats",
            Json::obj(vec![
                ("lookups", u64_json(s.lookups)),
                ("hits", u64_json(s.hits)),
                ("misses", u64_json(s.misses)),
                ("insertions", u64_json(s.insertions)),
                ("warm_seeds", u64_json(s.warm_seeds)),
                ("evictions", u64_json(s.evictions)),
            ]),
        ),
        ("entries", Json::arr(entries)),
    ])
}

fn plan_cache_from(j: &Json, shape: &ModelShape) -> Result<PlanCache> {
    let shape_key = ShapeKey::of(shape);
    let mut entries = HashMap::new();
    let mut order = Vec::new();
    for e in j.field("entries")?.as_arr()? {
        let k = e.field("key")?;
        let key = PlanKey {
            usable: k
                .field("usable")?
                .as_arr()?
                .iter()
                .map(|b| b.as_bool())
                .collect::<Result<Vec<bool>>>()?,
            calibration: u64_field(k, "calibration")?,
            shape: shape_key.clone(),
            planner: planner_kind_from(k.field("planner")?)?,
            seed: u64_field(k, "seed")?,
        };
        let value = CachedPlan {
            plan: plan_chain_from(e.field("plan")?)?,
            energy_j: f64_field(e, "energy_j")?,
            archive: e
                .field("archive")?
                .as_arr()?
                .iter()
                .map(pareto_from)
                .collect::<Result<Vec<_>>>()?,
        };
        entries.insert(key.clone(), value);
        order.push(key);
    }
    let s = j.field("stats")?;
    Ok(PlanCache {
        entries,
        order,
        cap: j.usize_field("cap")?,
        stats: PlanCacheStats {
            lookups: u64_field(s, "lookups")?,
            hits: u64_field(s, "hits")?,
            misses: u64_field(s, "misses")?,
            insertions: u64_field(s, "insertions")?,
            warm_seeds: u64_field(s, "warm_seeds")?,
            evictions: u64_field(s, "evictions")?,
        },
    })
}

fn replan_event_json(e: &ReplanEvent) -> Json {
    Json::obj(vec![
        ("at_s", f64_bits(e.at_s)),
        ("version", u64_json(e.version)),
        ("calibration_version", u64_json(e.calibration_version)),
        ("planner", Json::Str(e.planner.into())),
        ("plan_energy_j", f64_bits(e.plan_energy_j)),
        (
            "plan_error",
            match &e.plan_error {
                Some(s) => Json::Str(s.clone()),
                None => Json::Null,
            },
        ),
        ("cache_hit", Json::Bool(e.cache_hit)),
        ("warm_restart", Json::Bool(e.warm_restart)),
        ("plan", plan_chain_json(&e.plan)),
    ])
}

fn replan_event_from(j: &Json) -> Result<ReplanEvent> {
    let planner: &'static str = match j.str_field("planner")? {
        "pgsam" => "pgsam",
        "greedy" => "greedy",
        "none" => "none",
        other => bail!("unknown planner label {other:?}"),
    };
    Ok(ReplanEvent {
        at_s: f64_field(j, "at_s")?,
        version: u64_field(j, "version")?,
        calibration_version: u64_field(j, "calibration_version")?,
        planner,
        plan_energy_j: f64_field(j, "plan_energy_j")?,
        plan_error: match j.field("plan_error")? {
            Json::Null => None,
            other => Some(other.as_str()?.to_string()),
        },
        cache_hit: j.field("cache_hit")?.as_bool()?,
        warm_restart: j.field("warm_restart")?.as_bool()?,
        plan: plan_chain_from(j.field("plan")?)?,
    })
}

fn rls_json(r: &RatioRls) -> Json {
    Json::obj(vec![
        ("theta", f64_bits(r.theta)),
        ("p", f64_bits(r.p)),
        ("lambda", f64_bits(r.lambda)),
        ("samples", u64_json(r.samples)),
    ])
}

fn rls_from(j: &Json) -> Result<RatioRls> {
    Ok(RatioRls {
        theta: f64_field(j, "theta")?,
        p: f64_field(j, "p")?,
        lambda: f64_field(j, "lambda")?,
        samples: u64_field(j, "samples")?,
    })
}

fn ph_json(p: &PageHinkley) -> Json {
    Json::obj(vec![
        ("delta", f64_bits(p.delta)),
        ("lambda", f64_bits(p.lambda)),
        ("up", f64_bits(p.up)),
        ("down", f64_bits(p.down)),
        ("fires", u64_json(p.fires)),
    ])
}

fn ph_from(j: &Json) -> Result<PageHinkley> {
    Ok(PageHinkley {
        delta: f64_field(j, "delta")?,
        lambda: f64_field(j, "lambda")?,
        up: f64_field(j, "up")?,
        down: f64_field(j, "down")?,
        fires: u64_field(j, "fires")?,
    })
}

fn overlay_json(o: &CalibratedSpec) -> Json {
    Json::obj(vec![
        ("compute_scale", f64_bits(o.compute_scale)),
        ("bandwidth_scale", f64_bits(o.bandwidth_scale)),
        ("idle_scale", f64_bits(o.idle_scale)),
        ("power_scale", f64_bits(o.power_scale)),
        ("overhead_scale", f64_bits(o.overhead_scale)),
    ])
}

fn overlay_from(j: &Json) -> Result<CalibratedSpec> {
    Ok(CalibratedSpec {
        compute_scale: f64_field(j, "compute_scale")?,
        bandwidth_scale: f64_field(j, "bandwidth_scale")?,
        idle_scale: f64_field(j, "idle_scale")?,
        power_scale: f64_field(j, "power_scale")?,
        overhead_scale: f64_field(j, "overhead_scale")?,
    })
}

fn calibrator_json(c: &FleetCalibrator) -> Json {
    let devices: Vec<Json> = c
        .devices
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("compute_time", rls_json(&d.compute_time)),
                ("memory_time", rls_json(&d.memory_time)),
                ("active_power", rls_json(&d.active_power)),
                ("idle_power", rls_json(&d.idle_power)),
                ("detect_compute_time", ph_json(&d.detect_compute_time)),
                ("detect_memory_time", ph_json(&d.detect_memory_time)),
                ("detect_power", ph_json(&d.detect_power)),
                ("detect_idle", ph_json(&d.detect_idle)),
                ("applied", overlay_json(&d.applied)),
                ("version", u64_json(d.version)),
                ("samples", u64_json(d.samples)),
                ("err_sum", f64_bits(d.err_sum)),
                ("err_n", u64_json(d.err_n)),
                ("recent_err", f64_bits(d.recent_err)),
            ])
        })
        .collect();
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("rls_forgetting", f64_bits(c.config.rls_forgetting)),
                ("ph_delta", f64_bits(c.config.ph_delta)),
                ("ph_lambda", f64_bits(c.config.ph_lambda)),
                ("recent_err_decay", f64_bits(c.config.recent_err_decay)),
            ]),
        ),
        ("devices", Json::arr(devices)),
    ])
}

fn calibrator_from(j: &Json) -> Result<FleetCalibrator> {
    let cj = j.field("config")?;
    let config = CalibrationConfig {
        rls_forgetting: f64_field(cj, "rls_forgetting")?,
        ph_delta: f64_field(cj, "ph_delta")?,
        ph_lambda: f64_field(cj, "ph_lambda")?,
        recent_err_decay: f64_field(cj, "recent_err_decay")?,
    };
    let devices = j
        .field("devices")?
        .as_arr()?
        .iter()
        .map(|d| {
            Ok(DeviceCalibration {
                compute_time: rls_from(d.field("compute_time")?)?,
                memory_time: rls_from(d.field("memory_time")?)?,
                active_power: rls_from(d.field("active_power")?)?,
                idle_power: rls_from(d.field("idle_power")?)?,
                detect_compute_time: ph_from(d.field("detect_compute_time")?)?,
                detect_memory_time: ph_from(d.field("detect_memory_time")?)?,
                detect_power: ph_from(d.field("detect_power")?)?,
                detect_idle: ph_from(d.field("detect_idle")?)?,
                applied: overlay_from(d.field("applied")?)?,
                version: u64_field(d, "version")?,
                samples: u64_field(d, "samples")?,
                err_sum: f64_field(d, "err_sum")?,
                err_n: u64_field(d, "err_n")?,
                recent_err: f64_field(d, "recent_err")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(FleetCalibrator { config, devices })
}

// ---------------------------------------------------------------------
// Engine state: named components
// ---------------------------------------------------------------------

/// Names of the engine state components, in serialization order. The
/// desync detector digests and compares each independently.
pub const COMPONENTS: [&str; 13] = [
    "fleet",
    "shape",
    "options",
    "devices",
    "ledger",
    "latencies",
    "latency_law",
    "clock",
    "cascade",
    "plan_cache",
    "replan",
    "calibration",
    "des",
];

/// Serialize the full engine state as an object of named components.
pub fn engine_state(e: &SimEngine) -> Json {
    Json::obj(vec![
        ("fleet", fleet_json(&e.fleet)),
        ("shape", shape_json(&e.shape)),
        ("options", options_json(&e.options)),
        (
            "devices",
            Json::arr(e.devices.iter().map(|(id, d)| device_json(id, d)).collect()),
        ),
        ("ledger", ledger_json(&e.ledger)),
        ("latencies", latencies_json(&e.latencies)),
        (
            "latency_law",
            Json::obj(vec![
                ("overhead_const_s", f64_bits(e.latency_law.overhead_const_s)),
                ("overhead_log_coeff", f64_bits(e.latency_law.overhead_log_coeff)),
            ]),
        ),
        (
            "clock",
            Json::obj(vec![
                ("clock_s", f64_bits(e.clock_s)),
                ("tokens", u64_json(e.tokens)),
                (
                    "recoveries",
                    Json::arr(e.recoveries.iter().map(|&r| f64_bits(r)).collect()),
                ),
                ("failures", u64_json(e.failures)),
                ("queries_lost", Json::Num(e.queries_lost as f64)),
                ("samples_run_total", u64_json(e.samples_run_total)),
                ("solved", Json::Num(e.solved as f64)),
                ("accuracy_hits", Json::Num(e.accuracy_hits as f64)),
                ("queries_done", Json::Num(e.queries_done as f64)),
                ("pjrt_time_scale", f64_bits(e.pjrt_time_scale)),
                (
                    "noise_rng",
                    Json::obj(vec![
                        ("state", u64_json(e.noise_rng.state)),
                        ("inc", u64_json(e.noise_rng.inc)),
                    ]),
                ),
            ]),
        ),
        ("cascade", cascade_json(&e.cascade)),
        ("plan_cache", plan_cache_json(&e.plan_cache)),
        (
            "replan",
            Json::obj(vec![
                (
                    "last_planned_version",
                    match e.last_planned_version {
                        Some((v, cv)) => Json::arr(vec![u64_json(v), u64_json(cv)]),
                        None => Json::Null,
                    },
                ),
                ("replans", u64_json(e.replans)),
                ("plan_cache_hits", u64_json(e.plan_cache_hits)),
                (
                    "trail",
                    Json::arr(e.replan_trail.iter().map(replan_event_json).collect()),
                ),
            ]),
        ),
        (
            "calibration",
            Json::obj(vec![
                ("calibrator", calibrator_json(&e.calibrator)),
                ("calibrated_fleet", fleet_json(&e.calibrated_fleet)),
                ("calibrated_version", u64_json(e.calibrated_version)),
                ("table_rebuilds", u64_json(e.table_rebuilds)),
            ]),
        ),
        ("des", des_json(&e.des)),
    ])
}

/// Serialize the discrete-event scheduling state: the failure-schedule
/// cursor, every component's clock domain, and the staged window
/// intervals. `pending_idle_j` is transient within one tick (Fold's
/// divider is pinned at 1) and `window_ids` is derivable from the
/// devices component, so neither serializes.
fn des_json(d: &DesState) -> Json {
    Json::obj(vec![
        ("failure_cursor", u64_json(d.failures.cursor() as u64)),
        (
            "components",
            Json::arr(
                d.scheduler
                    .domains()
                    .map(|(id, dom)| {
                        Json::obj(vec![
                            ("stage", Json::Str(id.stage.as_str().into())),
                            ("index", Json::Num(id.index as f64)),
                            ("divider", u64_json(dom.divider)),
                            ("next_tick", u64_json(dom.next_tick)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "pending_dt",
            Json::arr(d.pending_dt.iter().map(|&v| f64_bits(v)).collect()),
        ),
    ])
}

fn des_from(
    j: &Json,
    devices: &BTreeMap<DeviceId, SimDevice>,
    options: &SimOptions,
) -> Result<DesState> {
    // Rebuild the derivable parts (window ids, expanded failure
    // schedule) from the already-restored components, then overlay the
    // serialized cursor and clock domains.
    let mut des = SimEngine::build_des(devices, options);
    des.failures.set_cursor(j.usize_field("failure_cursor")?);
    let mut scheduler = Scheduler::new();
    for c in j.field("components")?.as_arr()? {
        let name = c.str_field("stage")?;
        let Some(stage) = Stage::from_str(name) else {
            bail!("unknown DES stage {name:?}");
        };
        let index = c.usize_field("index")?;
        if index > u16::MAX as usize {
            bail!("DES component index {index} out of range");
        }
        scheduler.register(
            ComponentId::new(stage, index as u16),
            u64_field(c, "divider")?,
            u64_field(c, "next_tick")?,
        );
    }
    if scheduler.len() != des.scheduler.len() {
        bail!(
            "DES component table has {} entries, engine registers {}",
            scheduler.len(),
            des.scheduler.len()
        );
    }
    des.scheduler = scheduler;
    let pending_dt = j
        .field("pending_dt")?
        .as_arr()?
        .iter()
        .map(f64_from)
        .collect::<Result<Vec<f64>>>()?;
    if pending_dt.len() != des.window_ids.len() {
        bail!(
            "pending_dt has {} entries for {} devices",
            pending_dt.len(),
            des.window_ids.len()
        );
    }
    des.pending_dt = pending_dt;
    Ok(des)
}

/// Rebuild a `SimEngine` from an `engine_state` document.
pub fn engine_from_state(j: &Json) -> Result<SimEngine> {
    let fleet = fleet_from(j.field("fleet")?).context("component fleet")?;
    let shape = shape_from(j.field("shape")?).context("component shape")?;
    let options = options_from(j.field("options")?).context("component options")?;

    let devices = j
        .field("devices")?
        .as_arr()?
        .iter()
        .map(device_from)
        .collect::<Result<BTreeMap<DeviceId, SimDevice>>>()
        .context("component devices")?;

    let des = des_from(j.field("des")?, &devices, &options).context("component des")?;

    let clock = j.field("clock")?;
    let rng = clock.field("noise_rng")?;
    let noise_rng = Pcg {
        state: u64_field(rng, "state")?,
        inc: u64_field(rng, "inc")?,
    };

    let law = j.field("latency_law")?;
    let replan = j.field("replan")?;
    let cal = j.field("calibration")?;

    Ok(SimEngine {
        fleet,
        shape: shape.clone(),
        options,
        devices,
        ledger: ledger_from(j.field("ledger")?).context("component ledger")?,
        latencies: latencies_from(j.field("latencies")?).context("component latencies")?,
        latency_law: LatencyLaw {
            overhead_const_s: f64_field(law, "overhead_const_s")?,
            overhead_log_coeff: f64_field(law, "overhead_log_coeff")?,
        },
        clock_s: f64_field(clock, "clock_s")?,
        tokens: u64_field(clock, "tokens")?,
        recoveries: clock
            .field("recoveries")?
            .as_arr()?
            .iter()
            .map(f64_from)
            .collect::<Result<Vec<f64>>>()?,
        failures: u64_field(clock, "failures")?,
        queries_lost: clock.usize_field("queries_lost")?,
        samples_run_total: u64_field(clock, "samples_run_total")?,
        cascade: cascade_from(j.field("cascade")?).context("component cascade")?,
        plan_cache: plan_cache_from(j.field("plan_cache")?, &shape)
            .context("component plan_cache")?,
        last_planned_version: match replan.field("last_planned_version")? {
            Json::Null => None,
            pair => {
                let pair = pair.as_arr()?;
                if pair.len() != 2 {
                    bail!("last_planned_version must be [safety, calibration]");
                }
                Some((u64_from(&pair[0])?, u64_from(&pair[1])?))
            }
        },
        replans: u64_field(replan, "replans")?,
        plan_cache_hits: u64_field(replan, "plan_cache_hits")?,
        replan_trail: replan
            .field("trail")?
            .as_arr()?
            .iter()
            .map(replan_event_from)
            .collect::<Result<Vec<_>>>()
            .context("component replan")?,
        calibrator: calibrator_from(cal.field("calibrator")?)
            .context("component calibration")?,
        calibrated_fleet: fleet_from(cal.field("calibrated_fleet")?)
            .context("component calibration")?,
        calibrated_version: u64_field(cal, "calibrated_version")?,
        table_rebuilds: u64_field(cal, "table_rebuilds")?,
        noise_rng,
        solved: clock.usize_field("solved")?,
        accuracy_hits: clock.usize_field("accuracy_hits")?,
        queries_done: clock.usize_field("queries_done")?,
        pjrt_time_scale: f64_field(clock, "pjrt_time_scale")?,
        des,
        // Observability is harness state outside the snapshot format:
        // a restored engine always starts obs-off, whatever the donor
        // binary recorded — the harness re-arms it if it wants a trace.
        obs: crate::obs::Obs::disabled(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_bits_roundtrip_edge_cases() {
        for v in [
            0.0,
            -0.0,
            1.0,
            -1.5,
            1e300,
            -1e-300,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            std::f64::consts::PI,
        ] {
            let back = f64_from(&f64_bits(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "value {v}");
        }
        // NaN round-trips its exact payload (equality is on bits).
        let nan = f64::from_bits(0x7ff8_dead_beef_0001);
        assert_eq!(f64_from(&f64_bits(nan)).unwrap().to_bits(), nan.to_bits());
        // -0.0 keeps its sign (the Num writer's integer fast path would
        // drop it — this is why f64s do not ride as Json::Num).
        assert!(f64_from(&f64_bits(-0.0)).unwrap().is_sign_negative());
    }

    #[test]
    fn u64_roundtrip_above_2_53() {
        for v in [0u64, 1, (1 << 53) - 1, 1 << 53, u64::MAX, 0xCA11_B7A7_0000_0001] {
            assert_eq!(u64_from(&u64_json(v)).unwrap(), v, "value {v}");
        }
        // Small values stay plain numbers (readable snapshots)...
        assert!(matches!(u64_json(42), Json::Num(_)));
        // ...big ones become exact decimal strings.
        assert!(matches!(u64_json(u64::MAX), Json::Str(_)));
    }

    #[test]
    fn spec_roundtrip_is_bit_exact() {
        for spec in [
            DeviceSpec::intel_cpu(),
            DeviceSpec::intel_npu(),
            DeviceSpec::nvidia_gpu(),
            DeviceSpec::qualcomm_npu(),
            DeviceSpec::cloud_gpu(),
        ] {
            let back = spec_from(&spec_json(&spec)).unwrap();
            assert_eq!(back.id, spec.id);
            assert_eq!(back.bandwidth_gbs.to_bits(), spec.bandwidth_gbs.to_bits());
            assert_eq!(back.tdp_w.to_bits(), spec.tdp_w.to_bits());
            assert_eq!(back.kernel_overhead_us.to_bits(), spec.kernel_overhead_us.to_bits());
            assert_eq!(back.launch_granularity, spec.launch_granularity);
            assert_eq!(back.cores, spec.cores);
        }
    }

    #[test]
    fn latency_recorder_roundtrip_preserves_infinity_min() {
        // A fresh recorder's min_s is +inf — the exact case decimal
        // encoding cannot represent.
        let rec = LatencyRecorder::new();
        let back = latencies_from(&latencies_json(&rec)).unwrap();
        assert!(back.min_s.is_infinite());
        let mut rec = LatencyRecorder::new();
        rec.record(0.25);
        rec.record(3.5e-4);
        let back = latencies_from(&latencies_json(&rec)).unwrap();
        assert_eq!(back.count(), 2);
        assert_eq!(back.mean_s().to_bits(), rec.mean_s().to_bits());
        assert_eq!(back.percentile_s(99.0).to_bits(), rec.percentile_s(99.0).to_bits());
    }
}
