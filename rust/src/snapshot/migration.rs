//! Snapshot format versioning and forward migration.
//!
//! A snapshot document is tagged with the `FORMAT_VERSION` it was
//! written at. `migrate` walks a document forward one version at a
//! time until it reaches the current format, so any historical
//! checkpoint a deployment kept on disk stays restorable after the
//! state schema grows. Each step is a small, total JSON→JSON rewrite;
//! a version newer than the binary understands is a hard error (never
//! guess at fields from the future).
//!
//! History:
//! - v1: initial engine snapshot format (PR 6 development form). The
//!   `clock` component had no `pjrt_time_scale` field — the scale was
//!   an implicit 1.0.
//! - v2: `clock.pjrt_time_scale` serialized explicitly (bit-pattern
//!   encoded like every other `f64`).
//! - v3: the engine grows a `des` component — discrete-event scheduler
//!   state (failure-schedule cursor, per-component clock domains,
//!   staged window wall-intervals). v2 engines ran the synchronous
//!   loop, which is the DES schedule with every divider at 1, so the
//!   defaults are fully derivable from the document itself.

use anyhow::{bail, Result};

use crate::json::Json;

/// The snapshot format this binary writes.
pub const FORMAT_VERSION: u64 = 3;

/// Document kind tag for engine snapshots.
pub const SNAPSHOT_KIND: &str = "qeil-engine-snapshot";

/// Document kind tag for event logs.
pub const LOG_KIND: &str = "qeil-event-log";

/// Migrate a parsed snapshot document forward to `FORMAT_VERSION`,
/// in place. Idempotent for current-version documents.
pub fn migrate(doc: &mut Json) -> Result<()> {
    let mut version = doc.field("format_version")?.as_u64()?;
    if version > FORMAT_VERSION {
        bail!(
            "snapshot format v{version} is newer than this binary's v{FORMAT_VERSION}; \
             refusing to guess at unknown fields"
        );
    }
    while version < FORMAT_VERSION {
        match version {
            1 => migrate_v1_to_v2(doc)?,
            2 => migrate_v2_to_v3(doc)?,
            v => bail!("no migration path from snapshot format v{v}"),
        }
        version += 1;
        if let Json::Obj(map) = doc {
            map.insert("format_version".into(), Json::Num(version as f64));
        }
    }
    Ok(())
}

/// v1 → v2: `clock.pjrt_time_scale` appears, defaulting to the exact
/// bit pattern of 1.0 (v1 engines always ran pure-analytic).
fn migrate_v1_to_v2(doc: &mut Json) -> Result<()> {
    let Json::Obj(map) = doc else {
        bail!("snapshot document must be an object");
    };
    let Some(Json::Obj(engine)) = map.get_mut("engine") else {
        bail!("snapshot document missing engine object");
    };
    let Some(Json::Obj(clock)) = engine.get_mut("clock") else {
        bail!("snapshot engine missing clock component");
    };
    clock
        .entry("pjrt_time_scale".to_string())
        .or_insert_with(|| Json::Str(format!("{:016x}", 1.0f64.to_bits())));
    Ok(())
}

/// v2 → v3: the engine gains the `des` component. Every default is
/// derived from the document: all components run divider 1 and are
/// due on the next tick (`clock.queries_done`), no wall interval is
/// staged, and the failure cursor counts the expanded hard
/// transitions (fail at `at_s`, recover at `at_s + recover_after_s`)
/// at or before the serialized clock — the rescan loop that wrote the
/// document derived device health from the clock alone, so those
/// transitions are already reflected in the `devices` component.
fn migrate_v2_to_v3(doc: &mut Json) -> Result<()> {
    fn hex_f64(j: &Json) -> Result<f64> {
        let s = j.as_str()?;
        let bits = u64::from_str_radix(s, 16)
            .map_err(|e| anyhow::anyhow!("bad f64 bit pattern {s:?}: {e}"))?;
        Ok(f64::from_bits(bits))
    }

    let engine = doc.field("engine")?;
    let clock = engine.field("clock")?;
    let clock_s = hex_f64(clock.field("clock_s")?)?;
    let next_tick = clock.field("queries_done")?.as_u64()?;
    let n_devices = engine.field("devices")?.as_arr()?.len();

    // Expand the plan the way `FailureSchedule::from_plan` does and
    // count the transitions already settled at the serialized clock.
    let mut settled = 0usize;
    for s in engine.field("options")?.field("failure_plan")?.as_arr()? {
        let hard = matches!(s.field("kind")?, Json::Str(k) if k == "crash" || k == "hang");
        if !hard {
            continue;
        }
        let at_s = hex_f64(s.field("at_s")?)?;
        if at_s <= clock_s {
            settled += 1;
        }
        if let r @ Json::Str(_) = s.field("recover_after_s")? {
            if at_s + hex_f64(r)? <= clock_s {
                settled += 1;
            }
        }
    }

    let mut components: Vec<Json> = Vec::new();
    {
        let mut push = |stage: &str, index: usize| {
            components.push(Json::obj(vec![
                ("stage", Json::Str(stage.into())),
                ("index", Json::Num(index as f64)),
                ("divider", Json::Num(1.0)),
                ("next_tick", Json::Num(next_tick as f64)),
            ]));
        };
        for stage in ["environment", "model", "planning", "execution"] {
            push(stage, 0);
        }
        for i in 0..n_devices {
            push("window", i);
        }
        push("fold", 0);
    }

    let des = Json::obj(vec![
        ("failure_cursor", Json::Num(settled as f64)),
        ("components", Json::arr(components)),
        (
            "pending_dt",
            Json::arr(vec![
                Json::Str(format!("{:016x}", 0.0f64.to_bits()));
                n_devices
            ]),
        ),
    ]);

    let Json::Obj(map) = doc else {
        bail!("snapshot document must be an object");
    };
    let Some(Json::Obj(engine)) = map.get_mut("engine") else {
        bail!("snapshot document missing engine object");
    };
    engine.entry("des".to_string()).or_insert(des);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn future_versions_are_refused() {
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num((FORMAT_VERSION + 1) as f64)),
            ("engine", Json::obj(vec![])),
        ]);
        let err = migrate(&mut doc).unwrap_err().to_string();
        assert!(err.contains("newer"), "got: {err}");
    }

    #[test]
    fn current_version_is_a_no_op() {
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num(FORMAT_VERSION as f64)),
            ("engine", Json::obj(vec![("clock", Json::obj(vec![]))])),
        ]);
        let before = doc.to_string();
        migrate(&mut doc).unwrap();
        assert_eq!(doc.to_string(), before);
    }

    fn bits(v: f64) -> Json {
        Json::Str(format!("{:016x}", v.to_bits()))
    }

    /// The minimal engine object the v2→v3 step reads from.
    fn v2_engine(clock_s: f64, queries_done: u64, n_devices: usize, plan: Vec<Json>) -> Json {
        Json::obj(vec![
            (
                "clock",
                Json::obj(vec![
                    ("clock_s", bits(clock_s)),
                    ("queries_done", Json::Num(queries_done as f64)),
                ]),
            ),
            ("devices", Json::arr(vec![Json::obj(vec![]); n_devices])),
            ("options", Json::obj(vec![("failure_plan", Json::arr(plan))])),
        ])
    }

    #[test]
    fn v1_gains_pjrt_time_scale() {
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num(1.0)),
            ("engine", v2_engine(0.0, 0, 0, vec![])),
        ]);
        migrate(&mut doc).unwrap();
        assert_eq!(doc.field("format_version").unwrap().as_u64().unwrap(), FORMAT_VERSION);
        let scale = doc
            .field("engine")
            .unwrap()
            .field("clock")
            .unwrap()
            .field("pjrt_time_scale")
            .unwrap();
        assert_eq!(scale, &Json::Str(format!("{:016x}", 1.0f64.to_bits())));
    }

    #[test]
    fn v2_gains_a_derived_des_component() {
        let plan = vec![
            // Hard failure fully settled at clock_s = 10: fail at 2,
            // recover at 2 + 3 = 5 → two consumed transitions.
            Json::obj(vec![
                ("device", Json::Str("npu0".into())),
                ("kind", Json::Str("crash".into())),
                ("at_s", bits(2.0)),
                ("recover_after_s", bits(3.0)),
            ]),
            // Fail settled, recover still in the future → one consumed.
            Json::obj(vec![
                ("device", Json::Str("gpu0".into())),
                ("kind", Json::Str("hang".into())),
                ("at_s", bits(8.0)),
                ("recover_after_s", bits(30.0)),
            ]),
            // Soft failures never enter the hard-transition schedule.
            Json::obj(vec![
                ("device", Json::Str("cpu0".into())),
                ("kind", Json::obj(vec![("error_rate", bits(0.5))])),
                ("at_s", bits(1.0)),
                ("recover_after_s", Json::Null),
            ]),
        ];
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num(2.0)),
            ("engine", v2_engine(10.0, 7, 2, plan)),
        ]);
        migrate(&mut doc).unwrap();
        assert_eq!(doc.field("format_version").unwrap().as_u64().unwrap(), FORMAT_VERSION);

        let des = doc.field("engine").unwrap().field("des").unwrap();
        assert_eq!(des.usize_field("failure_cursor").unwrap(), 3);

        let components = des.field("components").unwrap().as_arr().unwrap();
        // environment/model/planning/execution + one window per device + fold.
        assert_eq!(components.len(), 4 + 2 + 1);
        let windows: Vec<usize> = components
            .iter()
            .filter(|c| c.str_field("stage").unwrap() == "window")
            .map(|c| c.usize_field("index").unwrap())
            .collect();
        assert_eq!(windows, vec![0, 1]);
        for c in components {
            assert_eq!(c.usize_field("divider").unwrap(), 1);
            assert_eq!(c.usize_field("next_tick").unwrap(), 7, "due on the next tick");
        }

        let pending = des.field("pending_dt").unwrap().as_arr().unwrap();
        assert_eq!(pending.len(), 2);
        assert!(pending.iter().all(|p| p == &bits(0.0)), "no staged wall time");
    }
}
