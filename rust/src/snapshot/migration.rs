//! Snapshot format versioning and forward migration.
//!
//! A snapshot document is tagged with the `FORMAT_VERSION` it was
//! written at. `migrate` walks a document forward one version at a
//! time until it reaches the current format, so any historical
//! checkpoint a deployment kept on disk stays restorable after the
//! state schema grows. Each step is a small, total JSON→JSON rewrite;
//! a version newer than the binary understands is a hard error (never
//! guess at fields from the future).
//!
//! History:
//! - v1: initial engine snapshot format (PR 6 development form). The
//!   `clock` component had no `pjrt_time_scale` field — the scale was
//!   an implicit 1.0.
//! - v2: `clock.pjrt_time_scale` serialized explicitly (bit-pattern
//!   encoded like every other `f64`).

use anyhow::{bail, Result};

use crate::json::Json;

/// The snapshot format this binary writes.
pub const FORMAT_VERSION: u64 = 2;

/// Document kind tag for engine snapshots.
pub const SNAPSHOT_KIND: &str = "qeil-engine-snapshot";

/// Document kind tag for event logs.
pub const LOG_KIND: &str = "qeil-event-log";

/// Migrate a parsed snapshot document forward to `FORMAT_VERSION`,
/// in place. Idempotent for current-version documents.
pub fn migrate(doc: &mut Json) -> Result<()> {
    let mut version = doc.field("format_version")?.as_u64()?;
    if version > FORMAT_VERSION {
        bail!(
            "snapshot format v{version} is newer than this binary's v{FORMAT_VERSION}; \
             refusing to guess at unknown fields"
        );
    }
    while version < FORMAT_VERSION {
        match version {
            1 => migrate_v1_to_v2(doc)?,
            v => bail!("no migration path from snapshot format v{v}"),
        }
        version += 1;
        if let Json::Obj(map) = doc {
            map.insert("format_version".into(), Json::Num(version as f64));
        }
    }
    Ok(())
}

/// v1 → v2: `clock.pjrt_time_scale` appears, defaulting to the exact
/// bit pattern of 1.0 (v1 engines always ran pure-analytic).
fn migrate_v1_to_v2(doc: &mut Json) -> Result<()> {
    let Json::Obj(map) = doc else {
        bail!("snapshot document must be an object");
    };
    let Some(Json::Obj(engine)) = map.get_mut("engine") else {
        bail!("snapshot document missing engine object");
    };
    let Some(Json::Obj(clock)) = engine.get_mut("clock") else {
        bail!("snapshot engine missing clock component");
    };
    clock
        .entry("pjrt_time_scale".to_string())
        .or_insert_with(|| Json::Str(format!("{:016x}", 1.0f64.to_bits())));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn future_versions_are_refused() {
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num((FORMAT_VERSION + 1) as f64)),
            ("engine", Json::obj(vec![])),
        ]);
        let err = migrate(&mut doc).unwrap_err().to_string();
        assert!(err.contains("newer"), "got: {err}");
    }

    #[test]
    fn current_version_is_a_no_op() {
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num(FORMAT_VERSION as f64)),
            ("engine", Json::obj(vec![("clock", Json::obj(vec![]))])),
        ]);
        let before = doc.to_string();
        migrate(&mut doc).unwrap();
        assert_eq!(doc.to_string(), before);
    }

    #[test]
    fn v1_gains_pjrt_time_scale() {
        let mut doc = Json::obj(vec![
            ("format_version", Json::Num(1.0)),
            ("engine", Json::obj(vec![("clock", Json::obj(vec![]))])),
        ]);
        migrate(&mut doc).unwrap();
        assert_eq!(doc.field("format_version").unwrap().as_u64().unwrap(), FORMAT_VERSION);
        let scale = doc
            .field("engine")
            .unwrap()
            .field("clock")
            .unwrap()
            .field("pjrt_time_scale")
            .unwrap();
        assert_eq!(scale, &Json::Str(format!("{:016x}", 1.0f64.to_bits())));
    }
}
