//! Crash-recovery drills.
//!
//! A drill simulates the coordinator dying at an arbitrary tick
//! mid-run and recovering from durable state: run with checkpoints at
//! a fixed cadence, "kill" at the drilled tick (drop the live engine on
//! the floor), restore the last checkpoint at-or-before the kill,
//! replay the log suffix, and finish. The recovered run must produce a
//! [`SimReport`] EQUAL (bit-exact, via `PartialEq`) to the
//! uninterrupted reference, with an equal state digest.
//!
//! The process boundary is simulated for real: every checkpoint a
//! drill restores from goes through serialize → STRING → parse —
//! nothing survives the "crash" except bytes that would have been on
//! disk. Kill ticks can be pinned or fuzzed per seed, so repeated CI
//! runs sweep different crash points while any failure stays exactly
//! reproducible from its seed.

use anyhow::{bail, Result};

use crate::devices::fleet::{Fleet, FleetPreset};
use crate::json::Json;
use crate::obs::FlightRecorder;
use crate::rng::Pcg;
use crate::sim::engine::{SimEngine, SimOptions, SimReport};
use crate::snapshot::replay::{EventLog, ReplaySession};
use crate::snapshot::{engine_digest, restore_engine, snapshot_engine};
use crate::workload::generator::Query;

/// Outcome of one kill-point drill.
#[derive(Debug, Clone)]
pub struct DrillOutcome {
    pub preset: FleetPreset,
    /// Tick the coordinator was killed at.
    pub kill_tick: u64,
    /// Tick of the checkpoint the recovery restored from (≤ kill_tick).
    pub checkpoint_tick: u64,
    /// Recovered state digest == uninterrupted reference digest.
    pub digest_match: bool,
    /// Recovered report == uninterrupted reference report (bit-exact).
    pub report_match: bool,
    pub final_digest: u64,
    /// Rendered flight-recorder tail of the reference run, attached on
    /// a mismatch so a failed drill leaves a readable trace of the
    /// dispatches leading to the reference state.
    pub trace: Option<String>,
}

impl DrillOutcome {
    pub fn passed(&self) -> bool {
        self.digest_match && self.report_match
    }
}

/// Checkpointed reference run: steps the engine through the whole log,
/// cutting a serialized snapshot STRING every `checkpoint_every` ticks
/// (including tick 0, so a kill before the first cadence point can
/// still recover). Returns the checkpoints and the reference report.
fn checkpointed_run(
    engine: SimEngine,
    log: &EventLog,
    checkpoint_every: u64,
) -> Result<(Vec<(u64, String)>, SimReport, FlightRecorder)> {
    let mut session = ReplaySession::new(engine, log.clone())?;
    let mut checkpoints = vec![(0u64, snapshot_engine(session.engine()).to_string())];
    loop {
        if !session.step() {
            break;
        }
        let tick = session.cursor();
        if checkpoint_every > 0 && tick % checkpoint_every == 0 {
            checkpoints.push((tick, snapshot_engine(session.engine()).to_string()));
        }
    }
    // All events consumed; finish() settles the final replan and
    // stamps the digest.
    debug_assert_eq!(session.cursor(), log.events.len() as u64);
    let report = session.run_to_end();
    let trace = session.engine().obs().recorder.clone();
    Ok((checkpoints, report, trace))
}

/// Kill-at-`kill_tick` recovery: restore the newest checkpoint at or
/// before the kill, replay the log suffix, finish.
fn recover(
    checkpoints: &[(u64, String)],
    log: &EventLog,
    kill_tick: u64,
) -> Result<(u64, SimReport, u64)> {
    let Some((tick, text)) = checkpoints.iter().rev().find(|(t, _)| *t <= kill_tick) else {
        bail!("no checkpoint at or before kill tick {kill_tick}");
    };
    let engine = restore_engine(&Json::parse(text)?)?;
    if engine.queries_done() as u64 != *tick {
        bail!(
            "checkpoint tagged tick {tick} restored an engine at tick {}",
            engine.queries_done()
        );
    }
    let mut session = ReplaySession::new(engine, log.clone())?;
    let report = session.run_to_end();
    let digest = engine_digest(session.engine());
    Ok((*tick, report, digest))
}

/// Run the full drill matrix for one preset: an uninterrupted
/// reference, then one recovery per kill tick. `fuzz_kills` extra kill
/// points are drawn per-seed from a PCG stream — deterministic for a
/// given seed, different across seeds.
pub fn drill_preset(
    preset: FleetPreset,
    options: SimOptions,
    queries: &[Query],
    samples: u32,
    checkpoint_every: u64,
    kill_ticks: &[u64],
    fuzz_kills: usize,
) -> Result<Vec<DrillOutcome>> {
    if queries.is_empty() {
        bail!("drill needs a non-empty query set");
    }
    let fleet = Fleet::preset(preset);
    let shape = crate::coordinator::allocation::ModelShape::from_family(
        crate::workload::datasets::ModelFamily::Gpt2,
        &crate::experiments::runner::default_meta(crate::workload::datasets::ModelFamily::Gpt2),
    );
    let log = EventLog::from_queries(queries, samples);

    // Uninterrupted reference (no checkpoint I/O on the hot path is
    // needed for correctness, but running THROUGH the checkpointed
    // driver also proves cutting snapshots perturbs nothing). The
    // reference runs with the flight recorder ARMED while every
    // recovery runs obs-off (a restored engine always is): the drill's
    // own digest/report equality is then a live proof that
    // observability sits outside the snapshot semantics.
    let mut engine = SimEngine::new(fleet, shape, options);
    engine.enable_obs();
    let (checkpoints, reference, reference_trace) =
        checkpointed_run(engine, &log, checkpoint_every)?;
    let reference_digest = reference.state_digest;

    let n = queries.len() as u64;
    let mut kills: Vec<u64> = kill_ticks.iter().map(|&t| t.min(n - 1)).collect();
    let mut fuzz = Pcg::new(options_seed(&log, &checkpoints), 0xD811_D811);
    for _ in 0..fuzz_kills {
        kills.push(fuzz.next_u64() % n);
    }

    kills
        .into_iter()
        .map(|kill_tick| {
            let (checkpoint_tick, report, digest) = recover(&checkpoints, &log, kill_tick)?;
            let digest_match = digest == reference_digest;
            let report_match = report == reference;
            Ok(DrillOutcome {
                preset,
                kill_tick,
                checkpoint_tick,
                digest_match,
                report_match,
                final_digest: digest,
                trace: if digest_match && report_match {
                    None
                } else {
                    Some(reference_trace.render_text(48))
                },
            })
        })
        .collect()
}

/// Seed for the fuzzed kill points: tied to the run's own identity
/// (first checkpoint digest ⊕ log length) so different runs drill
/// different crash points while one run's drills stay reproducible.
fn options_seed(log: &EventLog, checkpoints: &[(u64, String)]) -> u64 {
    let base = crate::snapshot::fnv1a64(checkpoints[0].1.as_bytes());
    base ^ (log.events.len() as u64)
}

/// Drill every fleet preset with one options template. Returns all
/// outcomes; callers assert `.iter().all(DrillOutcome::passed)`.
pub fn drill_all_presets(
    options: &SimOptions,
    queries: &[Query],
    samples: u32,
    checkpoint_every: u64,
    kill_ticks: &[u64],
    fuzz_kills: usize,
) -> Result<Vec<DrillOutcome>> {
    let mut outcomes = Vec::new();
    for preset in FleetPreset::all() {
        outcomes.extend(drill_preset(
            preset,
            options.clone(),
            queries,
            samples,
            checkpoint_every,
            kill_ticks,
            fuzz_kills,
        )?);
    }
    Ok(outcomes)
}
