//! Event/command log and deterministic replay.
//!
//! The engine is a deterministic function of (initial state, arrival
//! stream): every other input — failures, drift, thermal physics, the
//! noise RNG — is either serialized state or derived from it. So the
//! ONLY thing the log must capture is each externally-sourced event
//! (one query arrival per engine tick) plus the per-query sample
//! budget. `restore(snapshot at tick k)` + `replay(events k..n)` then
//! reproduces the uninterrupted run bit-for-bit, which the state
//! digest certifies.
//!
//! The replay cursor is the engine's own `queries_done` tick: event
//! `k` applies iff the engine has stepped exactly `k` queries. A
//! session restored from a mid-run snapshot therefore skips the
//! already-applied prefix automatically — there is no separate cursor
//! to keep consistent (or to corrupt).

use anyhow::{bail, Context, Result};

use crate::json::Json;
use crate::sim::engine::{SimEngine, SimReport};
use crate::snapshot::migration::{FORMAT_VERSION, LOG_KIND};
use crate::snapshot::serialize::{f64_bits, f64_from, u64_from, u64_json};
use crate::workload::coverage::CoverageOracle;
use crate::workload::datasets::Dataset;
use crate::workload::generator::Query;

/// One externally-sourced event: the query that arrived at `tick`.
#[derive(Debug, Clone)]
pub struct LogEvent {
    /// Engine tick (query index) this event applies at.
    pub tick: u64,
    pub query: Query,
}

/// Append-only log of a run's external inputs.
#[derive(Debug, Clone)]
pub struct EventLog {
    /// Per-query sample budget the run was launched with (part of the
    /// command, not the engine state — two runs of one engine with
    /// different budgets are different runs).
    pub samples: u32,
    pub events: Vec<LogEvent>,
}

impl EventLog {
    /// Build the log for a run over `queries` (tick = arrival index).
    pub fn from_queries(queries: &[Query], samples: u32) -> EventLog {
        EventLog {
            samples,
            events: queries
                .iter()
                .enumerate()
                .map(|(i, q)| LogEvent { tick: i as u64, query: q.clone() })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format_version", Json::Num(FORMAT_VERSION as f64)),
            ("kind", Json::Str(LOG_KIND.into())),
            ("samples", Json::Num(self.samples as f64)),
            (
                "events",
                Json::arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("tick", u64_json(e.tick)),
                                ("id", u64_json(e.query.id)),
                                ("dataset", Json::Str(e.query.dataset.as_str().into())),
                                ("difficulty_p", f64_bits(e.query.difficulty_p)),
                                ("prompt_tokens", Json::Num(e.query.prompt_tokens as f64)),
                                ("output_tokens", Json::Num(e.query.output_tokens as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<EventLog> {
        let kind = doc.field("kind")?.as_str()?;
        if kind != LOG_KIND {
            bail!("expected a {LOG_KIND:?} document, got kind {kind:?}");
        }
        let version = doc.field("format_version")?.as_u64()?;
        if version > FORMAT_VERSION {
            bail!("event log format v{version} is newer than this binary's v{FORMAT_VERSION}");
        }
        let events = doc
            .field("events")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(LogEvent {
                    tick: u64_from(e.field("tick")?)?,
                    query: Query {
                        id: e.u64_field("id")?,
                        dataset: Dataset::from_str(e.str_field("dataset")?)?,
                        difficulty_p: f64_from(e.field("difficulty_p")?)
                            .context("difficulty_p")?,
                        prompt_tokens: e.u64_field("prompt_tokens")? as u32,
                        output_tokens: e.u64_field("output_tokens")? as u32,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // The tick sequence must be dense from 0 — a gap means the log
        // was truncated mid-stream and replay would silently skip work.
        for (i, e) in events.iter().enumerate() {
            if e.tick != i as u64 {
                bail!("event log tick {} at position {i}: log is not dense", e.tick);
            }
        }
        Ok(EventLog { samples: doc.u64_field("samples")? as u32, events })
    }
}

/// Drives an engine (fresh or snapshot-restored) through a log.
pub struct ReplaySession {
    engine: SimEngine,
    oracle: CoverageOracle,
    log: EventLog,
}

impl ReplaySession {
    /// Attach a log to an engine. The engine may already be mid-run
    /// (restored from a snapshot); replay resumes at its own tick. An
    /// engine that is AHEAD of the log is refused — the log cannot
    /// reproduce the state the engine is already in.
    pub fn new(engine: SimEngine, log: EventLog) -> Result<ReplaySession> {
        if engine.queries_done() > log.events.len() {
            bail!(
                "engine is at tick {} but the log only holds {} events",
                engine.queries_done(),
                log.events.len()
            );
        }
        // The oracle is a pure function of the seed — derived state,
        // not logged state.
        let oracle = CoverageOracle::new(engine.seed());
        Ok(ReplaySession { engine, oracle, log })
    }

    /// The next tick to apply (== events already applied).
    pub fn cursor(&self) -> u64 {
        self.engine.queries_done() as u64
    }

    /// Ticks remaining in the log.
    pub fn remaining(&self) -> u64 {
        self.log.events.len() as u64 - self.cursor()
    }

    /// Apply the next event. Returns false when the log is exhausted.
    pub fn step(&mut self) -> bool {
        let idx = self.engine.queries_done();
        let Some(event) = self.log.events.get(idx) else {
            return false;
        };
        debug_assert_eq!(event.tick, idx as u64);
        self.engine.step_query(&event.query, self.log.samples, &self.oracle);
        true
    }

    /// Replay every remaining event and produce the final report —
    /// bit-identical to the uninterrupted run's.
    pub fn run_to_end(&mut self) -> SimReport {
        while self.step() {}
        self.engine.finish()
    }

    pub fn engine(&self) -> &SimEngine {
        &self.engine
    }

    /// Surrender the engine (e.g. to snapshot it between steps).
    pub fn into_engine(self) -> SimEngine {
        self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::datasets::ModelFamily;
    use crate::workload::generator::WorkloadGenerator;

    #[test]
    fn log_roundtrip_preserves_every_event() {
        let gen = WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, 7);
        let queries = gen.queries(20);
        let log = EventLog::from_queries(&queries, 4);
        let text = log.to_json().to_string();
        let back = EventLog::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.samples, 4);
        assert_eq!(back.events.len(), 20);
        for (a, b) in log.events.iter().zip(back.events.iter()) {
            assert_eq!(a.tick, b.tick);
            assert_eq!(a.query.id, b.query.id);
            assert_eq!(a.query.difficulty_p.to_bits(), b.query.difficulty_p.to_bits());
            assert_eq!(a.query.prompt_tokens, b.query.prompt_tokens);
            assert_eq!(a.query.output_tokens, b.query.output_tokens);
        }
    }

    #[test]
    fn truncated_log_with_gap_is_refused() {
        let gen = WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, 7);
        let queries = gen.queries(3);
        let mut log = EventLog::from_queries(&queries, 2);
        log.events.remove(1);
        let doc = log.to_json();
        assert!(EventLog::from_json(&doc).is_err());
    }
}
