//! Canonical state digests.
//!
//! A digest is FNV-1a 64 over the COMPACT serialization of a state
//! document. Canonical because `Json::Obj` is a `BTreeMap` — object
//! keys serialize in one fixed order regardless of insertion order —
//! and every `f64` is encoded as its exact bit pattern (see
//! `serialize::f64_bits`), so two digests are equal iff the serialized
//! states are byte-identical, which for the engine means the state
//! trajectories were bit-identical.
//!
//! FNV-1a is NOT cryptographic; it certifies determinism against
//! itself, not against an adversary. It is tiny, dependency-free, and
//! stable across platforms, which is everything a desync probe needs.

use crate::json::Json;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over raw bytes.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash = FNV_OFFSET;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(FNV_PRIME);
    }
    hash
}

/// Digest of a JSON document's canonical compact serialization.
pub fn digest_json(doc: &Json) -> u64 {
    fnv1a64(doc.to_string().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_fnv_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn digest_is_insertion_order_independent() {
        let a = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Num(2.0))]);
        let b = Json::obj(vec![("y", Json::Num(2.0)), ("x", Json::Num(1.0))]);
        assert_eq!(digest_json(&a), digest_json(&b));
    }

    #[test]
    fn digest_discriminates_values() {
        let a = Json::obj(vec![("x", Json::Num(1.0))]);
        let b = Json::obj(vec![("x", Json::Num(2.0))]);
        assert_ne!(digest_json(&a), digest_json(&b));
    }
}
