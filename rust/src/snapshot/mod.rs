//! Snapshot/replay failover substrate.
//!
//! Three guarantees, each load-bearing for the others:
//!
//! 1. **Versioned state serialization** ([`serialize`], [`migration`]):
//!    the FULL engine state — fleet, per-device thermal/health/detector
//!    state, ledgers, plan cache, calibration estimators, RNG streams —
//!    round-trips through the hand-rolled JSON layer bit-exactly
//!    (`f64`s ride as IEEE-754 bit patterns). Documents carry a format
//!    version and migrate forward on restore.
//! 2. **Deterministic event-log replay** ([`replay`]): every
//!    externally-sourced event (query arrival) is recorded with its
//!    tick; `restore(snapshot)` + `replay(log suffix)` is bit-identical
//!    to the uninterrupted run. "Bit-identical" is not aspirational —
//!    it is checked by the canonical state digest ([`digest`]), an
//!    FNV-1a 64 over the canonical serialization, exported on every
//!    [`SimReport`](crate::sim::engine::SimReport).
//! 3. **Failure drills** ([`drill`], [`desync`]): a crash-recovery
//!    harness kills the coordinator at arbitrary (including per-seed
//!    fuzzed) ticks and asserts digest-equal continuation on every
//!    fleet preset; a cross-replica comparator runs two replicas from
//!    one log and reports the first divergence tick and the first
//!    diverging state COMPONENT (the serialization is
//!    component-grouped precisely so divergence localizes).

pub mod cli;
pub mod desync;
pub mod digest;
pub mod drill;
pub mod migration;
pub mod replay;
pub mod serialize;

use anyhow::{bail, Context, Result};

use crate::json::Json;
use crate::sim::engine::SimEngine;

pub use digest::{digest_json, fnv1a64};
pub use migration::{FORMAT_VERSION, LOG_KIND, SNAPSHOT_KIND};
pub use serialize::COMPONENTS;

/// Serialize an engine into a versioned snapshot document.
pub fn snapshot_engine(engine: &SimEngine) -> Json {
    Json::obj(vec![
        ("format_version", Json::Num(FORMAT_VERSION as f64)),
        ("kind", Json::Str(SNAPSHOT_KIND.into())),
        ("engine", serialize::engine_state(engine)),
    ])
}

/// Rebuild an engine from a snapshot document, migrating older formats
/// forward first.
pub fn restore_engine(doc: &Json) -> Result<SimEngine> {
    let kind = doc.field("kind")?.as_str()?;
    if kind != SNAPSHOT_KIND {
        bail!("expected a {SNAPSHOT_KIND:?} document, got kind {kind:?}");
    }
    let mut doc = doc.clone();
    migration::migrate(&mut doc).context("snapshot migration")?;
    serialize::engine_from_state(doc.field("engine")?).context("snapshot restore")
}

/// Canonical digest of an engine's CURRENT state. Two engines with
/// equal digests serialized to byte-identical state — for a
/// deterministic engine, that means their entire trajectories matched.
pub fn engine_digest(engine: &SimEngine) -> u64 {
    digest_json(&serialize::engine_state(engine))
}

/// Per-component digests, in [`COMPONENTS`] order — the desync
/// comparator diffs these to NAME the first diverging subsystem
/// instead of reporting an opaque whole-state mismatch.
pub fn component_digests(engine: &SimEngine) -> Vec<(&'static str, u64)> {
    let state = serialize::engine_state(engine);
    COMPONENTS
        .iter()
        .map(|&name| {
            let digest = state.get(name).map(digest_json).unwrap_or(0);
            (name, digest)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::allocation::ModelShape;
    use crate::devices::fleet::{Fleet, FleetPreset};
    use crate::experiments::runner::default_meta;
    use crate::sim::engine::SimOptions;
    use crate::workload::datasets::ModelFamily;

    fn engine() -> SimEngine {
        let fleet = Fleet::preset(FleetPreset::EdgeBox);
        let meta = default_meta(ModelFamily::Gpt2);
        let shape = ModelShape::from_family(ModelFamily::Gpt2, &meta);
        SimEngine::new(fleet, shape, SimOptions::default())
    }

    #[test]
    fn fresh_engine_roundtrip_is_byte_identical() {
        let e = engine();
        let doc = snapshot_engine(&e);
        let text = doc.to_string();
        let restored = restore_engine(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(snapshot_engine(&restored).to_string(), text);
        assert_eq!(engine_digest(&restored), engine_digest(&e));
    }

    #[test]
    fn component_digests_cover_every_component() {
        let e = engine();
        let digests = component_digests(&e);
        assert_eq!(digests.len(), COMPONENTS.len());
        assert!(digests.iter().all(|&(_, d)| d != 0), "missing component in state doc");
    }

    #[test]
    fn wrong_kind_is_refused() {
        let doc = Json::obj(vec![
            ("format_version", Json::Num(FORMAT_VERSION as f64)),
            ("kind", Json::Str("qeil-event-log".into())),
            ("engine", Json::obj(vec![])),
        ]);
        assert!(restore_engine(&doc).is_err());
    }
}
