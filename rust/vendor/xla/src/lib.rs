//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links the native XLA/PJRT runtime, which the offline
//! build environment does not ship. This stub is API-compatible with the
//! subset `qeil::runtime` uses, compiles everywhere, and fails *late and
//! loudly*: [`PjRtClient::cpu`] returns an error, so any code path that
//! would actually execute an artifact reports "PJRT runtime unavailable"
//! instead of failing to link. The repo's runtime integration tests and
//! benches already skip themselves when `artifacts/manifest.json` is
//! absent, so `cargo test` stays green on a fresh offline checkout.
//!
//! To run against real hardware, point the `xla` dependency in the root
//! Cargo.toml at the upstream `xla-rs` crate instead of this stub — no
//! qeil source changes are needed.

use std::fmt::{self, Display};

/// Stub error type (implements `std::error::Error` so `?` conversion
/// into `anyhow::Error` works unchanged).
#[derive(Debug)]
pub struct Error(String);

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the native PJRT runtime; this build uses the offline stub \
         (swap the `xla` dependency for upstream xla-rs to execute artifacts)"
    )))
}

/// Parsed HLO module (the stub only checks the file is readable).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Element types transferable out of a [`Literal`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host-side tensor value. The stub can be constructed (so planner
/// code that merely builds inputs compiles and runs) but never carries
/// device data.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple3")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A device-side buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Matches xla-rs's generic-over-argument execute signature.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// The PJRT client. Construction fails in the stub — callers surface a
/// clear "runtime unavailable" error before any execution is attempted.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("PJRT"));
    }

    #[test]
    fn literals_construct_without_runtime() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.to_vec::<i32>().is_err());
        let s = Literal::scalar(7i32);
        assert!(s.to_tuple3().is_err());
    }
}
