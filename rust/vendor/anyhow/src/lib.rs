//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so qeil vendors
//! the small API subset it actually uses: [`Error`], [`Result`], the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!`,
//! `bail!`, `ensure!` macros. Semantics follow upstream anyhow closely:
//!
//! - `Error` wraps any `std::error::Error + Send + Sync + 'static` (or a
//!   plain message) plus a stack of context strings.
//! - `Error` deliberately does NOT implement `std::error::Error`, which
//!   is what lets the blanket `From<E: std::error::Error>` impl coexist
//!   with `Result<T, Error>` (upstream anyhow does the same).
//! - `Display` prints `outer context: ...: inner message`; `Debug` prints
//!   the same chain (upstream prints a backtrace we don't carry).

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error with a context chain.
pub struct Error {
    /// Context frames, outermost last (pushed by `.context(...)`).
    context: Vec<String>,
    message: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { context: Vec::new(), message: message.to_string(), source: None }
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            context: Vec::new(),
            message: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Attach another layer of context (outermost).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The lowest-level wrapped error, if one exists.
    pub fn root_cause(&self) -> Option<&(dyn StdError + 'static)> {
        let mut cur: &(dyn StdError + 'static) = match &self.source {
            Some(s) => s.as_ref(),
            None => return None,
        };
        while let Some(next) = cur.source() {
            cur = next;
        }
        Some(cur)
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.message)?;
        // Walk the wrapped error's own source chain for full diagnostics.
        if let Some(src) = &self.source {
            let mut cur = src.source();
            while let Some(e) = cur {
                write!(f, ": {e}")?;
                cur = e.source();
            }
        }
        Ok(())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Internal conversion trait so `Context` works both for foreign error
/// types and for `Error` itself (coherent because `Error` does not
/// implement `std::error::Error` — the upstream anyhow trick).
pub trait IntoAnyhow: Sized {
    fn into_anyhow(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoAnyhow for E {
    fn into_anyhow(self) -> Error {
        Error::new(self)
    }
}

impl IntoAnyhow for Error {
    fn into_anyhow(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoAnyhow> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err().context("loading app");
        let text = e.to_string();
        assert!(text.starts_with("loading app: reading config:"), "{text}");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert!(none.context("empty").is_err());
        let e: Error = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");

        fn guarded(v: u32) -> Result<u32> {
            ensure!(v < 10, "v too big: {v}");
            if v == 3 {
                bail!("three is right out");
            }
            Ok(v)
        }
        assert!(guarded(11).is_err());
        assert!(guarded(3).is_err());
        assert_eq!(guarded(2).unwrap(), 2);
    }

    #[test]
    fn with_context_on_anyhow_result() {
        fn inner() -> Result<u32> {
            Err(anyhow!("boom"))
        }
        let e = inner().with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom");
    }
}
