//! Integration tests over the serving stack: executor thread + service
//! front end with validation / gateway admission / sanity checks,
//! against the real PJRT engine (PJRT-touching tests skip when
//! artifacts are absent; the gateway admission tests run everywhere —
//! the shed ladder needs no engine).

use qeil::devices::spec::DevIdx;
use qeil::gateway::{
    AdmissionConfig, AdmissionController, AdmitDecision, DeviceTelemetry, FleetTelemetry,
    SlaClass,
};
use qeil::safety::thermal_guard::SHED_LEVELS;
use qeil::server::api::{InferenceRequest, RejectReason};
use qeil::server::service::{Service, ServiceConfig};

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn request(client: u32, prompt_len: usize, seed: u64) -> InferenceRequest {
    InferenceRequest {
        client_id: client,
        class: SlaClass::Standard,
        prompt: (0..prompt_len as i64).map(|i| i % 500).collect(),
        max_new_tokens: 6,
        temperature: 0.0,
        seed,
    }
}

/// A single-device snapshot pinned to one thermal shedding band.
fn snapshot_at_band(shed_level: u8) -> FleetTelemetry {
    FleetTelemetry {
        at_s: 0.0,
        safety_version: shed_level as u64,
        devices: vec![DeviceTelemetry {
            dev: DevIdx(0),
            dasi: 0.1,
            cpq: 0.2,
            phi: 1.0 - shed_level as f64 / SHED_LEVELS as f64,
            shed_level,
            temp_c: 60.0,
            schedulable: true,
            step_s: 1e-3,
            prefill_unit_s: 1e-5,
            active_power_w: 20.0,
        }],
    }
}

#[test]
fn shed_ladder_drops_batch_then_standard_then_interactive() {
    // The admission contract across every thermal band, driven straight
    // through the gateway controller (no artifacts needed): band 1
    // drops Batch, band 2 drops Standard, only the top band drops
    // Interactive — and the admitted set shrinks monotonically.
    let mut controller = AdmissionController::new(AdmissionConfig::default());
    let lanes = vec![DevIdx(0)];
    let mut previous: Option<Vec<SlaClass>> = None;
    for band in 0..=SHED_LEVELS {
        let snap = snapshot_at_band(band);
        let level = controller.effective_level(&snap, &lanes, 0.0);
        assert_eq!(level, band, "thermal band must pass through unchanged");
        let admitted: Vec<SlaClass> = SlaClass::all()
            .into_iter()
            .filter(|class| {
                matches!(
                    controller.admit(0, *class, band as f64, level),
                    AdmitDecision::Admit
                )
            })
            .collect();
        let expected: Vec<SlaClass> = match band {
            0 => SlaClass::all().to_vec(),
            1 => vec![SlaClass::Interactive, SlaClass::Standard],
            2 | 3 => vec![SlaClass::Interactive],
            _ => Vec::new(),
        };
        assert_eq!(admitted, expected, "band {band}");
        if let Some(prev) = &previous {
            assert!(
                admitted.iter().all(|c| prev.contains(c)),
                "band {band}: admitted set must shrink monotonically"
            );
        }
        previous = Some(admitted);
    }
}

#[test]
fn serves_valid_requests_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut service = Service::start(&ServiceConfig::default()).unwrap();
    for i in 0..3 {
        let resp = service.handle(request(i, 32, i as u64), i as f64).unwrap();
        assert_eq!(resp.tokens.len(), 6);
        assert!(resp.compute.as_secs_f64() > 0.0);
    }
    let stats = service.stats();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.tokens_out, 18);
    assert!(stats.mean_latency_s() > 0.0);
}

#[test]
fn validation_rejects_bad_prompts_before_compute() {
    if !have_artifacts() {
        return;
    }
    let mut service = Service::start(&ServiceConfig::default()).unwrap();
    // Oversized prompt (table 12's 10× context attack).
    let oversized = request(0, 320, 0);
    match service.handle(oversized, 0.0) {
        Err(RejectReason::Validation(msg)) => assert!(msg.contains("exceeds")),
        other => panic!("expected validation rejection, got {other:?}"),
    }
    // Out-of-vocab token.
    let mut bad = request(0, 32, 0);
    bad.prompt[0] = 100_000;
    assert!(matches!(service.handle(bad, 0.0), Err(RejectReason::Validation(_))));
    // Empty prompt.
    let mut empty = request(0, 32, 0);
    empty.prompt.clear();
    assert!(matches!(service.handle(empty, 0.0), Err(RejectReason::Validation(_))));
    let stats = service.stats();
    assert_eq!(stats.served, 0);
    assert_eq!(stats.rejected_validation, 3);
}

#[test]
fn rate_limiter_blocks_rapid_fire() {
    if !have_artifacts() {
        return;
    }
    let config = ServiceConfig { rate_per_s: 5.0, burst: 3.0, ..Default::default() };
    let mut service = Service::start(&config).unwrap();
    let mut admitted = 0;
    let mut limited = 0;
    for i in 0..20 {
        // All at t=0: only the burst should pass.
        match service.handle(request(9, 32, i), 0.0) {
            Ok(_) => admitted += 1,
            Err(RejectReason::RateLimited) => limited += 1,
            Err(other) => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(admitted, 3);
    assert_eq!(limited, 17);
}

#[test]
fn distinct_clients_unaffected_by_each_other() {
    if !have_artifacts() {
        return;
    }
    let config = ServiceConfig { rate_per_s: 5.0, burst: 1.0, ..Default::default() };
    let mut service = Service::start(&config).unwrap();
    assert!(service.handle(request(1, 32, 0), 0.0).is_ok());
    assert!(matches!(service.handle(request(1, 32, 1), 0.0), Err(RejectReason::RateLimited)));
    assert!(service.handle(request(2, 32, 2), 0.0).is_ok(), "client 2 must be unaffected");
}
