//! Integration tests over the serving stack: executor thread + service
//! front end with validation / rate limiting / sanity checks, against
//! the real PJRT engine. Skipped when artifacts are absent.

use qeil::server::api::{InferenceRequest, RejectReason};
use qeil::server::service::{Service, ServiceConfig};

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn request(client: u32, prompt_len: usize, seed: u64) -> InferenceRequest {
    InferenceRequest {
        client_id: client,
        prompt: (0..prompt_len as i64).map(|i| i % 500).collect(),
        max_new_tokens: 6,
        temperature: 0.0,
        seed,
    }
}

#[test]
fn serves_valid_requests_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut service = Service::start(&ServiceConfig::default()).unwrap();
    for i in 0..3 {
        let resp = service.handle(request(i, 32, i as u64), i as f64).unwrap();
        assert_eq!(resp.tokens.len(), 6);
        assert!(resp.compute.as_secs_f64() > 0.0);
    }
    let stats = service.stats();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.tokens_out, 18);
    assert!(stats.mean_latency_s() > 0.0);
}

#[test]
fn validation_rejects_bad_prompts_before_compute() {
    if !have_artifacts() {
        return;
    }
    let mut service = Service::start(&ServiceConfig::default()).unwrap();
    // Oversized prompt (table 12's 10× context attack).
    let oversized = request(0, 320, 0);
    match service.handle(oversized, 0.0) {
        Err(RejectReason::Validation(msg)) => assert!(msg.contains("exceeds")),
        other => panic!("expected validation rejection, got {other:?}"),
    }
    // Out-of-vocab token.
    let mut bad = request(0, 32, 0);
    bad.prompt[0] = 100_000;
    assert!(matches!(service.handle(bad, 0.0), Err(RejectReason::Validation(_))));
    // Empty prompt.
    let mut empty = request(0, 32, 0);
    empty.prompt.clear();
    assert!(matches!(service.handle(empty, 0.0), Err(RejectReason::Validation(_))));
    let stats = service.stats();
    assert_eq!(stats.served, 0);
    assert_eq!(stats.rejected_validation, 3);
}

#[test]
fn rate_limiter_blocks_rapid_fire() {
    if !have_artifacts() {
        return;
    }
    let config = ServiceConfig { rate_per_s: 5.0, burst: 3.0, ..Default::default() };
    let mut service = Service::start(&config).unwrap();
    let mut admitted = 0;
    let mut limited = 0;
    for i in 0..20 {
        // All at t=0: only the burst should pass.
        match service.handle(request(9, 32, i), 0.0) {
            Ok(_) => admitted += 1,
            Err(RejectReason::RateLimited) => limited += 1,
            Err(other) => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(admitted, 3);
    assert_eq!(limited, 17);
}

#[test]
fn distinct_clients_unaffected_by_each_other() {
    if !have_artifacts() {
        return;
    }
    let config = ServiceConfig { rate_per_s: 5.0, burst: 1.0, ..Default::default() };
    let mut service = Service::start(&config).unwrap();
    assert!(service.handle(request(1, 32, 0), 0.0).is_ok());
    assert!(matches!(service.handle(request(1, 32, 1), 0.0), Err(RejectReason::RateLimited)));
    assert!(service.handle(request(2, 32, 2), 0.0).is_ok(), "client 2 must be unaffected");
}
