//! Integration tests over the serving stack: executor pool + service
//! front end with validation / gateway admission / sanity checks,
//! against the real PJRT engine (PJRT-touching tests skip when
//! artifacts are absent; the gateway admission tests and the executor
//! pool / load-harness tests run everywhere — the shed ladder and the
//! synthetic-worker pool need no engine).

use qeil::devices::spec::DevIdx;
use qeil::gateway::{
    AdmissionConfig, AdmissionController, AdmitDecision, DeviceTelemetry, FleetTelemetry,
    SlaClass,
};
use qeil::safety::thermal_guard::SHED_LEVELS;
use qeil::server::api::{InferenceRequest, RejectReason};
use qeil::server::load::{run_load_harness, HarnessConfig, SyntheticWorker};
use qeil::server::pool::{ExecutorPool, PoolConfig, PoolJob};
use qeil::server::service::{Service, ServiceConfig};

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn request(client: u32, prompt_len: usize, seed: u64) -> InferenceRequest {
    InferenceRequest {
        client_id: client,
        class: SlaClass::Standard,
        prompt: (0..prompt_len as i64).map(|i| i % 500).collect(),
        max_new_tokens: 6,
        temperature: 0.0,
        seed,
    }
}

/// A single-device snapshot pinned to one thermal shedding band.
fn snapshot_at_band(shed_level: u8) -> FleetTelemetry {
    FleetTelemetry {
        at_s: 0.0,
        safety_version: shed_level as u64,
        devices: vec![DeviceTelemetry {
            dev: DevIdx(0),
            dasi: 0.1,
            cpq: 0.2,
            phi: 1.0 - shed_level as f64 / SHED_LEVELS as f64,
            shed_level,
            temp_c: 60.0,
            schedulable: true,
            step_s: 1e-3,
            prefill_unit_s: 1e-5,
            active_power_w: 20.0,
        }],
    }
}

#[test]
fn shed_ladder_drops_batch_then_standard_then_interactive() {
    // The admission contract across every thermal band, driven straight
    // through the gateway controller (no artifacts needed): band 1
    // drops Batch, band 2 drops Standard, only the top band drops
    // Interactive — and the admitted set shrinks monotonically.
    let mut controller = AdmissionController::new(AdmissionConfig::default());
    let lanes = vec![DevIdx(0)];
    let mut previous: Option<Vec<SlaClass>> = None;
    for band in 0..=SHED_LEVELS {
        let snap = snapshot_at_band(band);
        let level = controller.effective_level(&snap, &lanes, 0.0);
        assert_eq!(level, band, "thermal band must pass through unchanged");
        let admitted: Vec<SlaClass> = SlaClass::all()
            .into_iter()
            .filter(|class| {
                matches!(
                    controller.admit(0, *class, band as f64, level),
                    AdmitDecision::Admit
                )
            })
            .collect();
        let expected: Vec<SlaClass> = match band {
            0 => SlaClass::all().to_vec(),
            1 => vec![SlaClass::Interactive, SlaClass::Standard],
            2 | 3 => vec![SlaClass::Interactive],
            _ => Vec::new(),
        };
        assert_eq!(admitted, expected, "band {band}");
        if let Some(prev) = &previous {
            assert!(
                admitted.iter().all(|c| prev.contains(c)),
                "band {band}: admitted set must shrink monotonically"
            );
        }
        previous = Some(admitted);
    }
}

#[test]
fn pool_splits_queue_wait_from_service() {
    // The PR-8 satellite bugfix, pinned end to end: with ONE worker and
    // ~3 ms synthetic services, the second job's reported queue wait
    // must cover the first job's service time — the pre-pool executor
    // folded both into one `max(...)` number, so this wait was
    // invisible.
    let pool = ExecutorPool::new(PoolConfig { workers: 1, shards: 1, queue_depth: 8 });
    let responses = pool
        .run_scoped(
            |_| Ok(SyntheticWorker::with_mean_service_us(3000.0)),
            |pool| {
                let (tx, rx) = std::sync::mpsc::channel();
                for i in 0..2u32 {
                    pool.try_submit(PoolJob {
                        trace: None,
                        request: InferenceRequest {
                            client_id: i,
                            class: SlaClass::Standard,
                            prompt: vec![0; 32],
                            // 32 prompt + 16 output = exactly the
                            // worker's calibrated mean service.
                            max_new_tokens: 16,
                            temperature: 0.0,
                            seed: 0,
                        },
                        tenant: 0,
                        deadline_s: f64::INFINITY,
                        reply: Some(tx.clone()),
                    })
                    .unwrap_or_else(|_| panic!("submit must fit the queue"));
                }
                drop(tx);
                rx.iter().collect::<Vec<_>>()
            },
        )
        .unwrap();
    let responses: Vec<_> = responses.into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(responses.len(), 2);
    for resp in &responses {
        let wait = resp.queue_wait.as_secs_f64();
        let service = resp.service.as_secs_f64();
        let latency = resp.latency.as_secs_f64();
        assert!(service >= 2.5e-3, "spin worker must serve ~3 ms, got {service}");
        assert!(
            wait + service <= latency + 1e-3,
            "components must not exceed e2e: {wait} + {service} vs {latency}"
        );
        assert!(
            latency - (wait + service) < 5e-3,
            "components must reconstruct e2e: {wait} + {service} vs {latency}"
        );
    }
    let max_wait =
        responses.iter().map(|r| r.queue_wait.as_secs_f64()).fold(0.0, f64::max);
    assert!(
        max_wait >= 2e-3,
        "the serialized second job must report its wait behind the first, got {max_wait}"
    );
}

#[test]
fn hostile_tenant_churn_is_bounded() {
    // Half the traffic is the hostile tenant with a FRESH client id per
    // request; the amortized eviction sweep (the previously-dead
    // `evict_idle`, now wired into admission) must keep the limiter's
    // tracked-client set bounded instead of one entry per request.
    let config = HarnessConfig {
        requests: 20_000,
        overload: 10.0,
        hostile_fraction: 0.5,
        service_us: 20.0,
        ..Default::default()
    };
    let report = run_load_harness(&config).unwrap();
    report.verify().unwrap();
    assert!(
        report.limiter_clients < config.requests / 4,
        "limiter must evict churned ids: {} clients tracked after {} requests",
        report.limiter_clients,
        config.requests
    );
}

#[test]
fn overload_hit_rates_follow_class_order_through_the_pool() {
    // 10x overload through the REAL pool (workers, sharded EDF queues,
    // occupancy shedding, limiter): strict class priority must show up
    // as ordered deadline-hit rates and ordered queue-wait tails.
    let config = HarnessConfig { requests: 30_000, overload: 10.0, ..Default::default() };
    let report = run_load_harness(&config).unwrap();
    report.verify().unwrap();
    assert_eq!(report.processed(), config.requests as u64);

    let interactive = report.class(SlaClass::Interactive);
    let standard = report.class(SlaClass::Standard);
    let batch = report.class(SlaClass::Batch);
    // Small additive slack: hit rates are wall-clock measurements.
    assert!(
        interactive.hit_rate() + 0.02 >= standard.hit_rate(),
        "Interactive hit rate {:.3} must not trail Standard {:.3}",
        interactive.hit_rate(),
        standard.hit_rate()
    );
    assert!(
        standard.hit_rate() + 0.02 >= batch.hit_rate(),
        "Standard hit rate {:.3} must not trail Batch {:.3}",
        standard.hit_rate(),
        batch.hit_rate()
    );
    assert!(
        interactive.hit_rate() > batch.hit_rate(),
        "at 10x overload the class ladder must actually separate: I {:.3} vs B {:.3}",
        interactive.hit_rate(),
        batch.hit_rate()
    );
    // Queue-wait p99 follows the same order (1.25x multiplicative slack,
    // links with too few samples skipped).
    let p99 = |c: &qeil::server::load::ClassReport| {
        (c.pool.histograms.queue_wait.count(), c.pool.histograms.queue_wait.percentile_s(99.0))
    };
    let (ni, pi) = p99(interactive);
    let (ns, ps) = p99(standard);
    let (nb, pb) = p99(batch);
    if ni >= 50 && ns >= 50 {
        assert!(pi <= 1.25 * ps, "Interactive p99 wait {pi:.6} vs Standard {ps:.6}");
    }
    if ns >= 50 && nb >= 50 {
        assert!(ps <= 1.25 * pb, "Standard p99 wait {ps:.6} vs Batch {pb:.6}");
    }
}

#[test]
fn burst_arrivals_and_thrash_preserve_accounting_closure() {
    // Same-instant bursts pinned to one tenant hammer a single shard
    // row (the overflow path) while thrash phases flood and drain the
    // queues; every request must still land on exactly one terminal
    // ledger entry.
    let config = HarnessConfig {
        requests: 15_000,
        overload: 20.0,
        burst: 64,
        burst_every: 250,
        thrash_block: 500,
        ..Default::default()
    };
    let report = run_load_harness(&config).unwrap();
    report.verify().unwrap();
    assert_eq!(report.processed(), config.requests as u64);
    let overflow: u64 = report.classes.iter().map(|c| c.pool.overflow).sum();
    let expired: u64 = report.classes.iter().map(|c| c.pool.expired).sum();
    assert!(
        overflow + expired > 0,
        "a 20x overload run with 64-wide same-instant bursts must exercise the \
         overflow/expiry paths (overflow {overflow}, expired {expired})"
    );
}

#[test]
fn serves_valid_requests_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut service = Service::start(&ServiceConfig::default()).unwrap();
    for i in 0..3 {
        let resp = service.handle(request(i, 32, i as u64), i as f64).unwrap();
        assert_eq!(resp.tokens.len(), 6);
        assert!(resp.compute.as_secs_f64() > 0.0);
    }
    let stats = service.stats();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.tokens_out, 18);
    assert!(stats.mean_latency_s() > 0.0);
}

#[test]
fn validation_rejects_bad_prompts_before_compute() {
    if !have_artifacts() {
        return;
    }
    let mut service = Service::start(&ServiceConfig::default()).unwrap();
    // Oversized prompt (table 12's 10× context attack).
    let oversized = request(0, 320, 0);
    match service.handle(oversized, 0.0) {
        Err(RejectReason::Validation(msg)) => assert!(msg.contains("exceeds")),
        other => panic!("expected validation rejection, got {other:?}"),
    }
    // Out-of-vocab token.
    let mut bad = request(0, 32, 0);
    bad.prompt[0] = 100_000;
    assert!(matches!(service.handle(bad, 0.0), Err(RejectReason::Validation(_))));
    // Empty prompt.
    let mut empty = request(0, 32, 0);
    empty.prompt.clear();
    assert!(matches!(service.handle(empty, 0.0), Err(RejectReason::Validation(_))));
    let stats = service.stats();
    assert_eq!(stats.served, 0);
    assert_eq!(stats.rejected_validation, 3);
}

#[test]
fn rate_limiter_blocks_rapid_fire() {
    if !have_artifacts() {
        return;
    }
    let config = ServiceConfig { rate_per_s: 5.0, burst: 3.0, ..Default::default() };
    let mut service = Service::start(&config).unwrap();
    let mut admitted = 0;
    let mut limited = 0;
    for i in 0..20 {
        // All at t=0: only the burst should pass.
        match service.handle(request(9, 32, i), 0.0) {
            Ok(_) => admitted += 1,
            Err(RejectReason::RateLimited) => limited += 1,
            Err(other) => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(admitted, 3);
    assert_eq!(limited, 17);
}

#[test]
fn distinct_clients_unaffected_by_each_other() {
    if !have_artifacts() {
        return;
    }
    let config = ServiceConfig { rate_per_s: 5.0, burst: 1.0, ..Default::default() };
    let mut service = Service::start(&config).unwrap();
    assert!(service.handle(request(1, 32, 0), 0.0).is_ok());
    assert!(matches!(service.handle(request(1, 32, 1), 0.0), Err(RejectReason::RateLimited)));
    assert!(service.handle(request(2, 32, 2), 0.0).is_ok(), "client 2 must be unaffected");
}
