//! Observability contract lockdown (PR 9).
//!
//! The flight recorder, metrics registry, and per-component profiler
//! are harness state STRICTLY OUTSIDE the digest semantics: arming
//! them must not move a single bit of any `SimReport` or state digest,
//! on any preset (the seven paper presets AND the 100-device metro
//! stress preset), under any schedule mode (Legacy, Canonical,
//! Fuzzed). A snapshot cut from an obs-armed engine must be
//! byte-identical to one cut from an obs-off twin — same format
//! version, no new fields — and restore into an obs-off engine that
//! continues bit-identically.
//!
//! The metro default calibration-refresh divider
//! (`apply_default_dividers`) is locked down here too: it engages only
//! on large fleets, serializes through the component clock domains,
//! and a divided metro run is bit-stable across a mid-run
//! serialize/restore cycle.

use qeil::coordinator::allocation::ModelShape;
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::experiments::runner::default_meta;
use qeil::json::Json;
use qeil::sim::engine::{SimEngine, SimOptions};
use qeil::sim::ScheduleMode;
use qeil::snapshot::{engine_digest, restore_engine, snapshot_engine};
use qeil::workload::coverage::CoverageOracle;
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::{Query, WorkloadGenerator};

fn shape() -> ModelShape {
    ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2))
}

fn queries(seed: u64, n: usize) -> Vec<Query> {
    WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, seed).queries(n)
}

fn engine(preset: FleetPreset, options: SimOptions) -> SimEngine {
    SimEngine::new(Fleet::preset(preset), shape(), options)
}

/// Run one engine through `qs`, returning (report, post-finish digest).
fn run(mut e: SimEngine, qs: &[Query], samples: u32) -> (qeil::sim::engine::SimReport, u64) {
    let oracle = CoverageOracle::new(e.seed());
    for q in qs {
        e.step_query(q, samples, &oracle);
    }
    let report = e.finish();
    (report, engine_digest(&e))
}

// ---------------------------------------------------------------------
// Obs-on vs obs-off bit-identity, all presets × all schedule modes
// ---------------------------------------------------------------------

#[test]
fn obs_on_and_obs_off_runs_are_bit_identical_on_every_preset() {
    let schedules =
        [ScheduleMode::Legacy, ScheduleMode::Canonical, ScheduleMode::Fuzzed(0xC0FFEE)];
    for preset in FleetPreset::all() {
        let qs = queries(13, 24);
        for schedule in schedules {
            let options = SimOptions { seed: 13, schedule, ..SimOptions::default() };

            let plain = engine(preset, options.clone());
            let mut armed = engine(preset, options);
            armed.enable_obs();
            assert!(armed.obs().is_enabled());

            // Snapshot identity BEFORE running: obs must not appear in
            // the serialized form at all (no format bump, no field).
            assert_eq!(
                snapshot_engine(&armed).to_string(),
                snapshot_engine(&plain).to_string(),
                "{preset:?}/{schedule:?}: obs leaked into the snapshot"
            );

            let oracle = CoverageOracle::new(plain.seed());
            let mut plain = plain;
            for q in &qs {
                let a = plain.step_query(q, 4, &oracle);
                let b = armed.step_query(q, 4, &oracle);
                assert_eq!(a, b, "{preset:?}/{schedule:?}: step outcome diverged");
            }
            let report_plain = plain.finish();
            let report_armed = armed.finish();
            assert_eq!(
                report_armed, report_plain,
                "{preset:?}/{schedule:?}: SimReport moved under observation"
            );
            assert_eq!(
                engine_digest(&armed),
                engine_digest(&plain),
                "{preset:?}/{schedule:?}: state digest moved under observation"
            );
            assert!(
                armed.obs().recorder.total_recorded() > 0,
                "{preset:?}/{schedule:?}: armed run recorded nothing"
            );
            assert_eq!(plain.obs().recorder.total_recorded(), 0);
        }
    }
}

#[test]
fn obs_runs_are_bit_identical_on_metro_under_all_schedules() {
    // The fleet-scale preset separately: 100 devices = 105 components
    // per tick, so a short log already sweeps the whole dispatch
    // surface (including the default Model-stage divider, which metro
    // is large enough to engage).
    let schedules =
        [ScheduleMode::Legacy, ScheduleMode::Canonical, ScheduleMode::Fuzzed(0xBEEF)];
    let qs = queries(29, 8);
    for schedule in schedules {
        let options = SimOptions { seed: 29, schedule, ..SimOptions::default() };
        let mut plain = engine(FleetPreset::Metro, options.clone());
        let mut armed = engine(FleetPreset::Metro, options);
        if !matches!(schedule, ScheduleMode::Legacy) {
            // Apply the production divider to BOTH replicas — the
            // contract under test is obs-neutrality, with the divider
            // as deployed.
            assert!(plain.apply_default_dividers());
            assert!(armed.apply_default_dividers());
        }
        armed.enable_obs();
        let (report_plain, digest_plain) = run(plain, &qs, 2);
        let oracle = CoverageOracle::new(armed.seed());
        for q in &qs {
            armed.step_query(q, 2, &oracle);
        }
        let report_armed = armed.finish();
        assert_eq!(report_armed, report_plain, "metro/{schedule:?}: report moved");
        assert_eq!(
            engine_digest(&armed),
            digest_plain,
            "metro/{schedule:?}: digest moved"
        );
        assert!(armed.obs().recorder.total_recorded() > 0);
        assert!(
            armed.obs().profiler.len() > 0,
            "metro/{schedule:?}: profiler recorded no component self-time"
        );
    }
}

// ---------------------------------------------------------------------
// Snapshot neutrality: obs-armed snapshots restore obs-off, unchanged
// ---------------------------------------------------------------------

#[test]
fn mid_run_obs_snapshot_restores_into_an_obs_off_engine_unchanged() {
    let qs = queries(41, 30);
    let options = SimOptions { seed: 41, ..SimOptions::default() };
    let mut armed = engine(FleetPreset::EdgeBox, options.clone());
    armed.enable_obs();
    let mut twin = engine(FleetPreset::EdgeBox, options);
    let oracle = CoverageOracle::new(armed.seed());
    for q in &qs[..15] {
        armed.step_query(q, 4, &oracle);
        twin.step_query(q, 4, &oracle);
    }

    // The mid-run snapshot of the armed engine is byte-identical to
    // the obs-off twin's — same format version, nothing extra.
    let text = snapshot_engine(&armed).to_string();
    assert_eq!(text, snapshot_engine(&twin).to_string());

    // And it restores into an engine with observability OFF (the
    // recorder is process state, not snapshot state), which then
    // continues bit-identically to the still-armed original.
    let mut restored = restore_engine(&Json::parse(&text).unwrap()).unwrap();
    assert!(!restored.obs().is_enabled(), "restore must come back obs-off");
    assert_eq!(restored.obs().recorder.total_recorded(), 0);
    for q in &qs[15..] {
        let a = armed.step_query(q, 4, &oracle);
        let b = restored.step_query(q, 4, &oracle);
        assert_eq!(a, b);
        assert_eq!(engine_digest(&restored), engine_digest(&armed));
    }
    assert_eq!(restored.finish(), armed.finish());
}

// ---------------------------------------------------------------------
// Metro default calibration-refresh divider
// ---------------------------------------------------------------------

#[test]
fn default_divider_engages_only_on_large_fleets() {
    let mut metro = engine(FleetPreset::Metro, SimOptions::default());
    assert!(metro.apply_default_dividers(), "metro (100 devices) must take the divider");
    for preset in FleetPreset::all() {
        let mut e = engine(preset, SimOptions::default());
        assert!(
            !e.apply_default_dividers(),
            "{preset:?} is below the device floor and must keep divider 1"
        );
    }
}

#[test]
fn divided_metro_run_is_bit_stable_across_serialize_restore() {
    // The first production consumer of `set_component_divider`: metro's
    // Model-stage calibration refresh runs on a slower clock domain.
    // The divided run must survive a mid-run serialize → string →
    // restore cycle bit-exactly (the divider travels in the snapshot's
    // clock domains, not in harness state).
    let qs = queries(53, 14);
    let options = SimOptions { seed: 53, ..SimOptions::default() };
    let mut straight = engine(FleetPreset::Metro, options.clone());
    assert!(straight.apply_default_dividers());
    let mut chopped = engine(FleetPreset::Metro, options);
    assert!(chopped.apply_default_dividers());

    let oracle = CoverageOracle::new(straight.seed());
    for q in &qs[..7] {
        straight.step_query(q, 2, &oracle);
        chopped.step_query(q, 2, &oracle);
    }
    // Process boundary: only the serialized string survives. The
    // restore must NOT need apply_default_dividers() again — the
    // serialized clock domains win.
    let text = snapshot_engine(&chopped).to_string();
    let mut chopped = restore_engine(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(snapshot_engine(&chopped).to_string(), text);
    for q in &qs[7..] {
        let a = straight.step_query(q, 2, &oracle);
        let b = chopped.step_query(q, 2, &oracle);
        assert_eq!(a, b);
    }
    assert_eq!(chopped.finish(), straight.finish());
    assert_eq!(engine_digest(&chopped), engine_digest(&straight));
}
