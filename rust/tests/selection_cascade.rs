//! Property tests over the EAC/ARDE/CSVET selection cascade, plus
//! sim-level guarantees: on every fleet preset the cascade spends no
//! more energy than the full budget and never costs coverage, and its
//! decisions are deterministic under a fixed seed.

use qeil::config::{ExperimentConfig, OrchestratorFeatures};
use qeil::devices::fleet::FleetPreset;
use qeil::experiments::runner::run_config;
use qeil::prop_assert;
use qeil::selection::{
    Candidate, Csvet, CsvetConfig, CsvetDecision, SelectionCascade, StopReason,
};
use qeil::testing::check;
use qeil::workload::datasets::{Dataset, ModelFamily};

fn cand(index: u32, lane: u32, score: f64, verified: bool, energy_j: f64) -> Candidate {
    Candidate { index, lane, score, verified, energy_j }
}

#[test]
fn prop_csvet_never_stops_before_its_confidence_threshold() {
    // Whatever the stream, a stop must carry its justification: a
    // verified sample for success stops; ≥ min_samples observations AND
    // the anytime confidence bound for futility stops; the full budget
    // for exhaustion.
    check("csvet stop validity", 400, |rng| {
        let budget = 1 + rng.below(60) as u32;
        let par = 1 + rng.below(6) as u32;
        let p = rng.range_f64(0.0, 0.4);
        let stream: Vec<bool> = (0..budget).map(|_| rng.chance(p)).collect();
        let cascade = SelectionCascade::default();
        let cfg = cascade.config.csvet.clone();
        let report =
            cascade.run(budget, par, |i| cand(i, i % par, 0.5, stream[i as usize], 1.0));
        prop_assert!(report.samples_drawn <= budget, "drew past the budget");
        prop_assert!(report.samples_drawn >= 1, "budget >= 1 must draw");
        let drawn = report.samples_drawn as usize;
        match report.stop_reason {
            StopReason::VerifiedWinner => {
                prop_assert!(
                    stream[..drawn].iter().any(|&v| v),
                    "success stop without a verified sample in the drawn prefix"
                );
                prop_assert!(
                    report.winner.as_ref().map(|w| w.verified) == Some(true),
                    "winner of a success stop must be verified"
                );
            }
            StopReason::Futility => {
                prop_assert!(
                    report.samples_drawn >= cfg.min_samples,
                    "futility before min_samples"
                );
                prop_assert!(
                    stream[..drawn].iter().all(|&v| !v),
                    "futility despite an observed success"
                );
                // Re-derive the confidence state at the stop and verify
                // the bound the stop claims.
                let mut cs = Csvet::new(cfg.clone());
                for &v in &stream[..drawn] {
                    cs.observe(v);
                }
                let remaining = (budget - report.samples_drawn) as f64;
                prop_assert!(
                    cs.p_ucb() * remaining < cfg.futility_epsilon,
                    "stopped without the bound: ucb {} × remaining {remaining}",
                    cs.p_ucb()
                );
            }
            StopReason::BudgetExhausted => {
                prop_assert!(
                    report.samples_drawn == budget,
                    "exhaustion must draw the full budget"
                );
            }
            StopReason::EmptyBudget => {
                prop_assert!(false, "budget >= 1 can never be empty");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_winner_survives_from_the_drawn_pool() {
    // The winner is always one of the drawn candidates, and with any
    // verified sample present the winner is verified (EAC's verified
    // bonus dominates energy discounts).
    check("cascade winner membership", 200, |rng| {
        let budget = 1 + rng.below(40) as u32;
        let par = 1 + rng.below(5) as u32;
        let stream: Vec<(f64, bool)> =
            (0..budget).map(|_| (rng.next_f64(), rng.chance(0.2))).collect();
        let cascade = SelectionCascade::default();
        let report = cascade.run(budget, par, |i| {
            let (score, verified) = stream[i as usize];
            cand(i, i % par, score, verified, 0.5 + (i % 3) as f64 * 0.5)
        });
        let w = report.winner.as_ref().expect("non-empty budget has a winner");
        prop_assert!(w.index < report.samples_drawn, "winner outside the drawn pool");
        let drawn = report.samples_drawn as usize;
        if stream[..drawn].iter().any(|&(_, v)| v) {
            prop_assert!(w.verified, "a verified candidate was drawn but did not win");
        }
        Ok(())
    });
}

#[test]
fn csvet_futility_never_fires_at_paper_scale_budgets() {
    // The guarantee the Table 4 comparison rests on: within S ≤ 20 the
    // default confidence sequence never futility-stops, so the cascade
    // is exactly coverage-preserving there.
    for budget in 1..=20u32 {
        let cascade = SelectionCascade::default();
        let report = cascade.run(budget, 4, |i| cand(i, i % 4, 0.3, false, 1.0));
        assert_eq!(report.samples_drawn, budget, "budget {budget}");
        assert_eq!(report.stop_reason, StopReason::BudgetExhausted, "budget {budget}");
    }
    // Direct CSVET view of the same property.
    let mut cs = Csvet::new(CsvetConfig::default());
    for i in 0..20u32 {
        cs.observe(false);
        assert_eq!(cs.decision(20 - i - 1), CsvetDecision::Continue);
    }
}

#[test]
fn cascade_energy_never_exceeds_full_budget_on_any_fleet_preset() {
    // Sim-level property across every fleet preset: enabling the
    // cascade lowers (or keeps) total energy at equal-or-better pass@k,
    // and saves strictly on presets where queries stop early.
    for preset in FleetPreset::all() {
        let base = ExperimentConfig {
            fleet: preset,
            queries: 60,
            seed: 0,
            ..ExperimentConfig::energy_aware(ModelFamily::Gpt2, Dataset::WikiText103)
        };
        let on = run_config(&base).unwrap();
        let off_cfg = ExperimentConfig {
            features: OrchestratorFeatures {
                selection_cascade: false,
                ..OrchestratorFeatures::full()
            },
            ..base.clone()
        };
        let off = run_config(&off_cfg).unwrap();
        assert!(
            on.energy_kj <= off.energy_kj + 1e-9,
            "{preset:?}: cascade energy {} > full-budget energy {}",
            on.energy_kj,
            off.energy_kj
        );
        assert!(
            on.pass_at_k_pct >= off.pass_at_k_pct - 1e-9,
            "{preset:?}: cascade lost coverage: {} vs {}",
            on.pass_at_k_pct,
            off.pass_at_k_pct
        );
        assert!(on.cascade_enabled && !off.cascade_enabled);
        assert!(
            on.cascade_samples_drawn <= on.cascade_samples_budgeted,
            "{preset:?}: drew past the budget"
        );
        assert!(
            on.cascade_samples_drawn < on.cascade_samples_budgeted,
            "{preset:?}: solvable workloads must stop some queries early"
        );
        assert!(on.cascade_energy_saved_kj > 0.0, "{preset:?}");
        assert_eq!(on.cascade_futility_stops, 0, "{preset:?}: futility inside S=20");
    }
}

#[test]
fn winner_is_deterministic_under_a_fixed_seed() {
    // Cascade level: identical streams give identical reports.
    let cascade = SelectionCascade::default();
    let make = |i: u32| cand(i, i % 3, (i as f64 * 0.37) % 1.0, i % 11 == 7, 1.0);
    let a = cascade.run(24, 3, make);
    let b = cascade.run(24, 3, make);
    assert_eq!(a.samples_drawn, b.samples_drawn);
    assert_eq!(a.stop_reason, b.stop_reason);
    assert_eq!(a.elimination_rounds, b.elimination_rounds);
    assert_eq!(
        a.winner.as_ref().map(|w| w.index),
        b.winner.as_ref().map(|w| w.index)
    );

    // Sim level: a fixed config seed reproduces the whole cascade trail.
    let cfg = ExperimentConfig {
        queries: 40,
        seed: 9,
        ..ExperimentConfig::energy_aware(ModelFamily::Gpt2, Dataset::WikiText103)
    };
    let m1 = run_config(&cfg).unwrap();
    let m2 = run_config(&cfg).unwrap();
    assert_eq!(m1.cascade_samples_drawn, m2.cascade_samples_drawn);
    assert_eq!(m1.cascade_success_stops, m2.cascade_success_stops);
    assert_eq!(m1.pass_at_k_pct.to_bits(), m2.pass_at_k_pct.to_bits());
    assert_eq!(m1.energy_kj.to_bits(), m2.energy_kj.to_bits());
}

#[test]
fn degenerate_inputs_do_not_panic() {
    let cascade = SelectionCascade::default();

    // 0 samples: nothing drawn, no winner, labeled empty.
    let r0 = cascade.run(0, 4, |i| cand(i, 0, 0.5, true, 1.0));
    assert_eq!(r0.samples_drawn, 0);
    assert!(r0.winner.is_none());
    assert_eq!(r0.stop_reason, StopReason::EmptyBudget);

    // 1 candidate, unverified: it wins by exhaustion.
    let r1 = cascade.run(1, 4, |i| cand(i, 0, 0.2, false, 1.0));
    assert_eq!(r1.samples_drawn, 1);
    assert_eq!(r1.stop_reason, StopReason::BudgetExhausted);
    assert_eq!(r1.winner.as_ref().map(|w| w.index), Some(0));

    // 1 candidate, verified: a verified-winner stop.
    let r1v = cascade.run(1, 4, |i| cand(i, 0, 0.9, true, 1.0));
    assert_eq!(r1v.stop_reason, StopReason::VerifiedWinner);
    assert_eq!(r1v.winner.as_ref().map(|w| w.index), Some(0));

    // All-tied scores: deterministic index tie-break picks the first.
    let rt = cascade.run(8, 2, |i| cand(i, i % 2, 0.5, false, 1.0));
    assert_eq!(rt.samples_drawn, 8);
    assert_eq!(rt.winner.as_ref().map(|w| w.index), Some(0));

    // Zero parallelism degrades to serial waves.
    let rz = cascade.run(5, 0, |i| cand(i, 0, 0.1, false, 1.0));
    assert_eq!(rz.samples_drawn, 5);

    // NaN scores must not break the total order or panic — and the
    // sanitized NaN candidate must lose to every real-scored one.
    let rn = cascade.run(4, 2, |i| {
        cand(i, i % 2, if i == 1 { f64::NAN } else { 0.5 }, false, 1.0)
    });
    assert_eq!(rn.winner.as_ref().map(|w| w.index), Some(0));
}

#[test]
fn prop_cascade_monotone_in_budget_on_all_failure_streams() {
    // With no successes, more budget never draws fewer samples (waves
    // only extend), and inside S ≤ 20 drawn == budget exactly.
    check("cascade budget monotonicity", 100, |rng| {
        let par = 1 + rng.below(6) as u32;
        let cascade = SelectionCascade::default();
        let mut prev = 0u32;
        for budget in [1u32, 2, 5, 10, 20] {
            let r = cascade.run(budget, par, |i| cand(i, i % par, 0.4, false, 1.0));
            prop_assert!(
                r.samples_drawn >= prev,
                "drawn fell from {prev} to {} at budget {budget}",
                r.samples_drawn
            );
            prop_assert!(r.samples_drawn == budget, "early stop inside S<=20");
            prev = r.samples_drawn;
        }
        Ok(())
    });
}
