//! Integration over the experiment harness: every paper table/figure
//! generator must run and reproduce the paper's qualitative claims.

use qeil::experiments::{run_experiment, ALL_IDS};

#[test]
fn every_experiment_generates() {
    for id in ALL_IDS {
        let t = run_experiment(id, 100, 0).unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(!t.rows.is_empty(), "{id}: empty table");
        assert!(!t.to_markdown().is_empty());
    }
}

#[test]
fn headline_claims_hold_at_full_scale() {
    // Table 16 at full scale: mean aggregate row carries the signs the
    // paper claims (IPW up, coverage up, energy down, latency down).
    let t = run_experiment("t16", 400, 0).unwrap();
    let mean = t.rows.last().unwrap();
    assert!(mean[2].starts_with('+'), "mean IPW gain: {}", mean[2]);
    assert!(mean[3].starts_with('+'), "mean coverage gain: {}", mean[3]);
    assert!(mean[4].starts_with('-'), "mean energy delta: {}", mean[4]);
    assert!(mean[7].starts_with('-'), "mean latency delta: {}", mean[7]);
}

#[test]
fn safety_tables_reproduce_guarantees() {
    // Table 10: guard -> zero throttle events.
    let t10 = run_experiment("t10", 100, 0).unwrap();
    assert_eq!(t10.rows[1][2], "0");
    // Table 11: zero queries lost in every scenario.
    let t11 = run_experiment("t11", 100, 0).unwrap();
    for row in &t11.rows {
        assert_eq!(row[3], "0", "{}", row[0]);
    }
    // Table 12: first two attacks blocked 100%.
    let t12 = run_experiment("t12", 100, 0).unwrap();
    assert_eq!(t12.rows[0][1], "100%");
    assert_eq!(t12.rows[1][1], "100%");
}

#[test]
fn table4_cascade_rung_dominates_the_adaptive_budget_rung() {
    // The new seventh rung: with `selection_cascade` enabled the sweep
    // must show strictly lower total energy at equal-or-better pass@k
    // than the adaptive-sample-budget rung, and be monotone in IPW
    // relative to it. (Verified-winner stops are exact for pass@k and
    // CSVET futility never fires inside S = 20, so the cascade can only
    // remove wasted decode work.)
    let t = run_experiment("t4", 100, 0).unwrap();
    assert_eq!(t.rows.len(), 7, "Table 4 must have seven rungs");
    assert_eq!(t.rows[4][0], "+ Adaptive Sample Budget");
    assert_eq!(t.rows[5][0], "+ Safety Constraints");
    assert_eq!(t.rows[6][0], "+ Selection Cascade");
    let cell = |r: usize, c: usize| -> f64 { t.rows[r][c].parse().unwrap() };
    assert!(
        cell(6, 2) < cell(4, 2),
        "cascade energy {} must be strictly below adaptive-budget energy {}",
        cell(6, 2),
        cell(4, 2)
    );
    assert!(
        cell(6, 1) >= cell(4, 1),
        "cascade pass@k {} fell below adaptive-budget pass@k {}",
        cell(6, 1),
        cell(4, 1)
    );
    assert!(
        cell(6, 3) >= cell(4, 3),
        "cascade IPW {} not monotone vs adaptive-budget IPW {}",
        cell(6, 3),
        cell(4, 3)
    );
    // Isolation: rungs 6 and 7 differ ONLY in the selection_cascade
    // flag, so this pair attributes the delta to the cascade alone (a
    // future safety-cost change cannot mask or fake it here).
    assert!(
        cell(6, 2) < cell(5, 2),
        "cascade-only energy delta missing: {} vs {}",
        cell(6, 2),
        cell(5, 2)
    );
    assert!(
        cell(6, 1) >= cell(5, 1),
        "cascade-only pass@k regressed: {} vs {}",
        cell(6, 1),
        cell(5, 1)
    );
}

#[test]
fn run_metrics_carry_planner_and_cascade_trail() {
    use qeil::config::ExperimentConfig;
    use qeil::experiments::runner::run_config;
    use qeil::workload::datasets::{Dataset, ModelFamily};

    let cfg = ExperimentConfig {
        queries: 40,
        ..ExperimentConfig::energy_aware(ModelFamily::Gpt2, Dataset::WikiText103)
    };
    let m = run_config(&cfg).unwrap();
    // Planner trail serializes through RunMetrics…
    assert_eq!(m.planner, "pgsam");
    assert!(m.plan_energy_j > 0.0);
    assert!(m.plan_error.is_none());
    // …and so does the cascade trail.
    assert!(m.cascade_enabled);
    assert!(m.cascade_samples_drawn >= 40, "every query draws at least one sample");
    assert!(m.cascade_samples_drawn <= m.cascade_samples_budgeted);
    assert!(m.cascade_energy_saved_kj > 0.0);
    assert_eq!(
        m.cascade_success_stops + m.cascade_futility_stops + m.cascade_exhausted_stops,
        40,
        "exactly one stop per query"
    );

    // With the cascade off the trail is absent and zeroed.
    let mut off = cfg.clone();
    off.features.selection_cascade = false;
    let m_off = run_config(&off).unwrap();
    assert!(!m_off.cascade_enabled);
    assert_eq!(m_off.cascade_samples_budgeted, 0);
    assert_eq!(m_off.cascade_samples_drawn, 0);
    assert!(
        m.mean_samples <= m_off.mean_samples,
        "cascade must never draw more samples than the full budget"
    );
}

#[test]
fn results_are_seed_stable() {
    let a = run_experiment("t3", 100, 5).unwrap();
    let b = run_experiment("t3", 100, 5).unwrap();
    assert_eq!(a.rows, b.rows, "same seed must give identical tables");
    let c = run_experiment("t3", 100, 6).unwrap();
    assert_ne!(a.rows, c.rows, "different seeds must differ somewhere");
}
