//! Integration over the experiment harness: every paper table/figure
//! generator must run and reproduce the paper's qualitative claims.

use qeil::experiments::{run_experiment, ALL_IDS};

#[test]
fn every_experiment_generates() {
    for id in ALL_IDS {
        let t = run_experiment(id, 100, 0).unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(!t.rows.is_empty(), "{id}: empty table");
        assert!(!t.to_markdown().is_empty());
    }
}

#[test]
fn headline_claims_hold_at_full_scale() {
    // Table 16 at full scale: mean aggregate row carries the signs the
    // paper claims (IPW up, coverage up, energy down, latency down).
    let t = run_experiment("t16", 400, 0).unwrap();
    let mean = t.rows.last().unwrap();
    assert!(mean[2].starts_with('+'), "mean IPW gain: {}", mean[2]);
    assert!(mean[3].starts_with('+'), "mean coverage gain: {}", mean[3]);
    assert!(mean[4].starts_with('-'), "mean energy delta: {}", mean[4]);
    assert!(mean[7].starts_with('-'), "mean latency delta: {}", mean[7]);
}

#[test]
fn safety_tables_reproduce_guarantees() {
    // Table 10: guard -> zero throttle events.
    let t10 = run_experiment("t10", 100, 0).unwrap();
    assert_eq!(t10.rows[1][2], "0");
    // Table 11: zero queries lost in every scenario.
    let t11 = run_experiment("t11", 100, 0).unwrap();
    for row in &t11.rows {
        assert_eq!(row[3], "0", "{}", row[0]);
    }
    // Table 12: first two attacks blocked 100%.
    let t12 = run_experiment("t12", 100, 0).unwrap();
    assert_eq!(t12.rows[0][1], "100%");
    assert_eq!(t12.rows[1][1], "100%");
}

#[test]
fn results_are_seed_stable() {
    let a = run_experiment("t3", 100, 5).unwrap();
    let b = run_experiment("t3", 100, 5).unwrap();
    assert_eq!(a.rows, b.rows, "same seed must give identical tables");
    let c = run_experiment("t3", 100, 6).unwrap();
    assert_ne!(a.rows, c.rows, "different seeds must differ somewhere");
}
