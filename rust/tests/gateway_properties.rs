//! Property tests over the serving gateway: EDF ordering, prefix-stable
//! per-tenant D'Hondt fairness, the SLA acceptance matrix (Interactive ≥
//! Standard ≥ Batch deadline hit-rates under overload on every fleet
//! preset), shed-ladder ordering, re-routing on safety-version bumps,
//! and bit-determinism under the logical clock. No artifacts, no wall
//! time — the whole subsystem runs on injected clocks and fixed seeds.

use qeil::coordinator::batcher::Batcher;
use qeil::devices::fleet::FleetPreset;
use qeil::devices::spec::{DevIdx, DeviceId};
use qeil::gateway::{
    FairShare, Gateway, GatewayConfig, GatewayReport, GatewayRequest, SlaClass, SlaQueues,
    TelemetryProbe, WaveScheduler,
};
use qeil::rng::Pcg;
use qeil::safety::thermal_guard::SHED_LEVELS;

fn overload_report(preset: FleetPreset, seed: u64) -> GatewayReport {
    let mut gateway = Gateway::new(GatewayConfig { fleet: preset, seed, ..Default::default() });
    let trace = gateway.overload_trace(240, 3.0, None);
    gateway.run_trace(&trace)
}

#[test]
fn edf_pop_order_is_earliest_deadline_first_per_tenant() {
    // Random insert order; pops must come out deadline-sorted with the
    // id tie-break, independently per (tenant, class).
    let mut rng = Pcg::seeded(11);
    let mut queues = SlaQueues::new(64);
    for id in 0..120u64 {
        let req = GatewayRequest {
            id,
            tenant: (rng.below(3)) as u32,
            class: SlaClass::all()[rng.below(3) as usize],
            arrival_s: 0.0,
            deadline_s: (rng.below(40) as f64) * 0.25,
            prompt_tokens: 32,
            output_tokens: 16,
        };
        queues.enqueue(req).unwrap();
    }
    for class in SlaClass::all() {
        for tenant in 0..3u32 {
            let mut prev: Option<(u64, u64)> = None;
            while let Some(req) = queues.pop_edf(class, tenant) {
                let key = (req.deadline_s.to_bits(), req.id);
                if let Some(p) = prev {
                    assert!(p <= key, "EDF violated for {class:?}/t{tenant}: {p:?} then {key:?}");
                }
                prev = Some(key);
            }
        }
    }
    assert_eq!(queues.total(), 0);
}

#[test]
fn fair_share_is_the_prefix_stable_dhondt_sequence() {
    // The gateway's tenant rule must be EXACTLY the batcher's
    // prefix-stable Jefferson/D'Hondt divisor sequence: same weights,
    // same owners, at every prefix.
    let weights = [5.0, 3.0, 2.0, 1.0, 1.0];
    let tenants: Vec<DeviceId> = (0..5).map(|i| DeviceId(format!("tenant{i}"))).collect();
    let batcher = Batcher { max_batch: 4096 };
    let n = 60u32;
    let mut owner = vec![usize::MAX; n as usize];
    for batch in batcher.assign_weighted(n, &tenants, &weights) {
        let ti = tenants.iter().position(|t| t == &batch.device).unwrap();
        for &slot in &batch.samples {
            owner[slot as usize] = ti;
        }
    }
    let mut fair = FairShare::new(&weights);
    let eligible = vec![true; 5];
    for (slot, &expected) in owner.iter().enumerate() {
        assert_eq!(
            fair.next(&eligible),
            Some(expected),
            "slot {slot} diverged from the batcher sequence"
        );
    }
    // Counts match the batcher apportionment exactly.
    let mut counts = vec![0u64; 5];
    for &o in &owner {
        counts[o] += 1;
    }
    assert_eq!(fair.assigned(), &counts[..]);
}

#[test]
fn overload_matrix_on_every_fleet_preset() {
    // The acceptance criteria, locked per preset under 3x overload:
    //  (1) Interactive >= Standard >= Batch deadline hit-rate,
    //  (2) shed drops strictly in ladder order,
    //  (3) accounting invariants close (nothing lost or double-counted),
    //  (4) admitted Interactive never starves (completed or expired),
    //  (5) the full run is bit-deterministic under the fixed seed.
    for preset in FleetPreset::all() {
        let report = overload_report(preset, 7);
        let name = preset.as_str();

        // (1) SLA ordering over SUBMITTED requests.
        let hit = |c: SlaClass| report.class(c).hit_rate();
        assert!(
            hit(SlaClass::Interactive) >= hit(SlaClass::Standard),
            "{name}: Interactive {} < Standard {}",
            hit(SlaClass::Interactive),
            hit(SlaClass::Standard)
        );
        assert!(
            hit(SlaClass::Standard) >= hit(SlaClass::Batch),
            "{name}: Standard {} < Batch {}",
            hit(SlaClass::Standard),
            hit(SlaClass::Batch)
        );
        assert!(hit(SlaClass::Interactive) > 0.0, "{name}: Interactive starved");

        // (2) Ladder order: if a higher class shed, every lower class
        // shed at a band no deeper; Interactive only at the top band.
        let first = |c: SlaClass| report.class(c).first_shed_level;
        if let Some(standard_band) = first(SlaClass::Standard) {
            let batch_band =
                first(SlaClass::Batch).expect("Standard shed implies Batch shed first");
            assert!(batch_band <= standard_band, "{name}: ladder inverted");
        }
        if let Some(band) = first(SlaClass::Interactive) {
            assert_eq!(band, SHED_LEVELS, "{name}: Interactive shed below the top band");
        }
        // Under 3x overload the backpressure band must engage on Batch.
        assert!(report.class(SlaClass::Batch).shed > 0, "{name}: overload must shed Batch");
        assert!(report.max_shed_level >= 1, "{name}: pressure bands never engaged");

        // (3) Accounting: submitted splits exactly into outcomes, and a
        // drained run leaves every admitted request completed|expired.
        for class in SlaClass::all() {
            let s = report.class(class);
            assert_eq!(
                s.submitted,
                s.admitted + s.shed + s.rate_limited + s.overflow,
                "{name}/{class:?}: admission accounting leak"
            );
            assert_eq!(
                s.admitted,
                s.completed + s.expired,
                "{name}/{class:?}: request lost in the queues"
            );
            assert!(s.deadline_hits <= s.completed);
            assert_eq!(s.submitted, 80, "{name}/{class:?}: equal class mix by construction");
        }

        // (4) follows from (3) for Interactive specifically; assert the
        // class actually saw service.
        assert!(report.class(SlaClass::Interactive).completed > 0, "{name}");

        // (5) Bit-determinism: identical config + trace => identical
        // report, f64 fields included.
        let replay = overload_report(preset, 7);
        assert_eq!(report, replay, "{name}: run is not bit-deterministic");

        // Sanity on the ledger: energy accrued and wall time advanced.
        assert!(report.energy_j > 0.0 && report.wall_s > 0.0, "{name}");
        assert!(report.waves > 0, "{name}");
    }
}

#[test]
fn tenant_shares_stay_fair_under_symmetric_overload() {
    // Equal weights + symmetric demand: cumulative D'Hondt keeps the
    // dispatched totals within a small band of each other on every
    // preset (exactly ±1 while all tenants stay backlogged; eligibility
    // gaps at the trace edges can widen it slightly).
    for preset in FleetPreset::all() {
        let report = overload_report(preset, 7);
        let dispatched = &report.per_tenant_dispatched;
        assert_eq!(dispatched.len(), 4);
        let max = *dispatched.iter().max().unwrap();
        let min = *dispatched.iter().min().unwrap();
        assert!(
            max - min <= 8,
            "{}: tenant dispatch spread too wide: {dispatched:?}",
            preset.as_str()
        );
        assert!(min > 0, "{}: a tenant starved entirely: {dispatched:?}", preset.as_str());
    }
}

#[test]
fn weighted_tenants_receive_proportional_service() {
    // Two tenants, weights 2:1, offered load matched 2:1 so both stay
    // backlogged: dispatched shares must track the weights.
    let config = GatewayConfig {
        tenants: 2,
        tenant_weights: Some(vec![2.0, 1.0]),
        seed: 7,
        ..Default::default()
    };
    let mut gateway = Gateway::new(config);
    let base = gateway.overload_trace(420, 3.0, None);
    // Remap tenants to the 2:1 offered pattern [0, 0, 1] per class round.
    let trace: Vec<GatewayRequest> = base
        .into_iter()
        .enumerate()
        .map(|(i, mut req)| {
            req.tenant = [0u32, 0, 1][(i / 3) % 3];
            req
        })
        .collect();
    let report = gateway.run_trace(&trace);
    let dispatched = &report.per_tenant_dispatched;
    assert!(dispatched[0] > 0 && dispatched[1] > 0);
    let ratio = dispatched[0] as f64 / dispatched[1] as f64;
    assert!(
        (1.5..=2.5).contains(&ratio),
        "2:1 weights must yield ~2:1 service, got {dispatched:?} (ratio {ratio:.2})"
    );
}

#[test]
fn safety_version_bump_reroutes_the_lanes() {
    // The PR-3 consumer contract on the gateway side: heating a device
    // across a shedding band bumps the monotone safety version, which
    // must invalidate the current lane route (a reroute, not a cache
    // wipe) while committed lane work is preserved.
    let fleet = qeil::devices::fleet::Fleet::preset(FleetPreset::EdgeBox);
    let shape = qeil::coordinator::allocation::ModelShape::from_family(
        qeil::workload::datasets::ModelFamily::Gpt2,
        &qeil::experiments::runner::default_meta(qeil::workload::datasets::ModelFamily::Gpt2),
    );
    let mut probe = TelemetryProbe::new(&fleet, &shape);
    let mut scheduler = WaveScheduler::new(&[1.0; 2]);
    let cold = probe.snapshot(0.0);
    scheduler.ensure_routes(&fleet, &shape, &cold, 4, 0.0);
    assert_eq!(scheduler.reroutes, 0);
    let lanes_cold = scheduler.lane_devs();
    assert!(!lanes_cold.is_empty());

    // Cook the dGPU at sustained TDP-grade draw until it crosses a
    // band (the only edge-box device whose TDP steady state exceeds
    // its guard point — the co-processors are guard-safe by design).
    let gpu = fleet.idx_of(&"gpu0".into()).unwrap();
    for _ in 0..300 {
        probe.record_busy(gpu, 1.0, 400.0);
        probe.advance(1.0);
    }
    let hot = probe.snapshot(300.0);
    assert!(hot.safety_version > cold.safety_version, "band crossing must bump the version");
    assert!(hot.devices[gpu.as_usize()].shed_level >= 1);
    assert!(hot.devices[gpu.as_usize()].phi < 1.0);

    scheduler.ensure_routes(&fleet, &shape, &hot, 4, 300.0);
    assert_eq!(scheduler.reroutes, 1, "version bump must re-derive the lanes");
    // Same version again: stable, no redundant reroute.
    scheduler.ensure_routes(&fleet, &shape, &hot, 4, 301.0);
    assert_eq!(scheduler.reroutes, 1);
}

#[test]
fn pinned_class_traces_respect_the_ladder_end_to_end() {
    // A Batch-only overload run shows the backpressure band shedding
    // Batch at band >= 1 while an Interactive-only run under the same
    // pressure admits everything (Interactive is never
    // backpressure-shed).
    let mut batch_gateway =
        Gateway::new(GatewayConfig { seed: 3, ..Default::default() });
    let batch_trace = batch_gateway.overload_trace(240, 3.0, Some(SlaClass::Batch));
    let batch_report = batch_gateway.run_trace(&batch_trace);
    let batch = batch_report.class(SlaClass::Batch);
    assert!(batch.shed > 0, "pure Batch overload must shed");
    assert_eq!(batch.first_shed_level.unwrap(), 1, "Batch drops at the first band");

    let mut interactive_gateway =
        Gateway::new(GatewayConfig { seed: 3, ..Default::default() });
    let interactive_trace =
        interactive_gateway.overload_trace(240, 3.0, Some(SlaClass::Interactive));
    let interactive_report = interactive_gateway.run_trace(&interactive_trace);
    let interactive = interactive_report.class(SlaClass::Interactive);
    assert_eq!(interactive.shed, 0, "Interactive is never backpressure-shed");
    assert_eq!(
        interactive.submitted,
        interactive.admitted + interactive.overflow,
        "only queue bounds may turn Interactive away"
    );
}

#[test]
fn wave_width_scales_with_free_lanes_not_backlog() {
    // Low wave_per_lane forces multiple waves; every admitted request
    // still completes or expires (continuous batching drains fully).
    let mut gateway = Gateway::new(GatewayConfig {
        wave_per_lane: 1,
        seed: 5,
        ..Default::default()
    });
    let trace = gateway.overload_trace(120, 2.0, None);
    let report = gateway.run_trace(&trace);
    assert!(report.waves >= 2);
    for class in SlaClass::all() {
        let s = report.class(class);
        assert_eq!(s.admitted, s.completed + s.expired);
    }
}

#[test]
fn devidx_lanes_resolve_against_the_preset_fleet() {
    // Lane indices in the report's busy ledger correspond to real fleet
    // devices and only routed lanes accumulate busy seconds.
    let report = overload_report(FleetPreset::EdgeBox, 7);
    let fleet = qeil::devices::fleet::Fleet::preset(FleetPreset::EdgeBox);
    assert_eq!(report.lane_busy_s.len(), fleet.len());
    let busy_total: f64 = report.lane_busy_s.iter().map(|(_, s)| *s).sum();
    assert!(busy_total > 0.0);
    for (id, _) in &report.lane_busy_s {
        assert!(fleet.get(&DeviceId(id.clone())).is_some(), "unknown device {id}");
    }
    // DevIdx round-trip sanity for the probe's snapshot indexing.
    let probe = TelemetryProbe::new(
        &fleet,
        &qeil::coordinator::allocation::ModelShape::from_family(
            qeil::workload::datasets::ModelFamily::Gpt2,
            &qeil::experiments::runner::default_meta(qeil::workload::datasets::ModelFamily::Gpt2),
        ),
    );
    let snap = probe.snapshot(0.0);
    for (i, d) in snap.devices.iter().enumerate() {
        assert_eq!(d.dev, DevIdx(i as u16));
    }
}

#[test]
fn failed_device_reroutes_lanes_and_recovery_routes_back() {
    // The PR-5 satellite lock for the PR-4 ROADMAP knob: DeviceHealth
    // feeds the probe, so a Failed device (not just a thermal band)
    // invalidates the lane route. The edge box routes its decode lanes
    // NPU-first; failing the NPU must reroute the lanes without it,
    // and recovery must route it back — each via one version bump.
    let fleet = qeil::devices::fleet::Fleet::preset(FleetPreset::EdgeBox);
    let shape = qeil::coordinator::allocation::ModelShape::from_family(
        qeil::workload::datasets::ModelFamily::Gpt2,
        &qeil::experiments::runner::default_meta(qeil::workload::datasets::ModelFamily::Gpt2),
    );
    let mut probe = TelemetryProbe::new(&fleet, &shape);
    let mut scheduler = WaveScheduler::new(&[1.0; 2]);
    let npu = fleet.idx_of(&DeviceId::from("npu0")).unwrap();

    let cold = probe.snapshot(0.0);
    scheduler.ensure_routes(&fleet, &shape, &cold, 4, 0.0);
    assert!(scheduler.lane_devs().contains(&npu), "healthy edge box routes the NPU");
    assert_eq!(scheduler.reroutes, 0);

    probe.mark_failed(npu, 1.0);
    let failed = probe.snapshot(1.0);
    assert!(failed.safety_version > cold.safety_version, "failure is a safety transition");
    assert!(!failed.devices[npu.as_usize()].schedulable);
    scheduler.ensure_routes(&fleet, &shape, &failed, 4, 1.0);
    assert_eq!(scheduler.reroutes, 1, "failure must re-derive the lanes");
    assert!(
        !scheduler.lane_devs().contains(&npu),
        "Failed device must leave the lane set: {:?}",
        scheduler.lane_devs()
    );
    assert!(!scheduler.lane_devs().is_empty(), "survivors keep serving");

    probe.mark_recovering(npu, 2.0);
    let recovered = probe.snapshot(2.0);
    assert!(recovered.safety_version > failed.safety_version);
    scheduler.ensure_routes(&fleet, &shape, &recovered, 4, 2.0);
    assert_eq!(scheduler.reroutes, 2, "recovery must re-derive the lanes again");
    assert!(
        scheduler.lane_devs().contains(&npu),
        "Recovering device is schedulable and rejoins the route"
    );
}

#[test]
fn des_dispatch_matches_the_direct_loop_on_every_preset() {
    // The serving loop re-hosted on the DES core must be an identity
    // refactor: popping one GatewayComponent off a Scheduler per tick
    // yields a report and state digest bit-identical to the direct
    // `run_trace` loop, under 3x overload, on every paper preset.
    for preset in FleetPreset::all() {
        let config = GatewayConfig { fleet: preset, seed: 7, ..Default::default() };
        let mut direct = Gateway::new(config.clone());
        let trace = direct.overload_trace(240, 3.0, None);
        let direct_report = direct.run_trace(&trace);

        let mut des = Gateway::new(config);
        let des_report = des.run_trace_des(&trace);
        assert_eq!(
            des_report,
            direct_report,
            "{}: DES serving loop diverged from the direct loop",
            preset.as_str()
        );
        assert_eq!(des.state_digest(), direct.state_digest(), "{}", preset.as_str());
    }
}

#[test]
fn gateway_run_with_failed_device_serves_around_it() {
    // End-to-end: fail the NPU before an overload run on the edge box.
    // The run must still complete work, and the failed device must
    // accumulate zero busy seconds (nothing was ever dispatched to it).
    let mut gateway = Gateway::new(GatewayConfig { seed: 9, ..Default::default() });
    assert!(gateway.fail_device(&DeviceId::from("npu0")));
    assert!(!gateway.fail_device(&DeviceId::from("nope")), "unknown ids are rejected");
    let trace = gateway.overload_trace(120, 2.0, None);
    let report = gateway.run_trace(&trace);
    let completed: u64 = report.classes.iter().map(|c| c.completed).sum();
    assert!(completed > 0, "the degraded fleet must keep serving");
    let npu_busy = report
        .lane_busy_s
        .iter()
        .find(|(id, _)| id == "npu0")
        .map(|(_, s)| *s)
        .unwrap();
    assert_eq!(npu_busy, 0.0, "no work may land on the failed device");
    let other_busy: f64 = report.lane_busy_s.iter().map(|(_, s)| *s).sum();
    assert!(other_busy > 0.0);
}
