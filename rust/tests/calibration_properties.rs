//! Property tests over the PR-5 online-calibration subsystem: the
//! zero-drift no-op guarantee (bit-identical to the uncalibrated path
//! on every fleet preset), convergence of the RLS estimates to
//! injected ground truth, drift-triggered replan invalidation with the
//! closed loop beating stale-coefficient plans, in-band contention
//! noise never firing the detector, and bit-determinism under a fixed
//! seed. Everything runs on the simulator's logical clock — no
//! artifacts, no wall time.

use qeil::calibration::{DriftPlan, DriftScenario, FleetCalibrator};
use qeil::config::OrchestratorFeatures;
use qeil::coordinator::allocation::ModelShape;
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::devices::spec::DevIdx;
use qeil::experiments::calibration_eval::{victim_device, DERATE_AT_S, DERATE_FACTOR};
use qeil::experiments::runner::default_meta;
use qeil::sim::engine::{SimEngine, SimOptions, SimReport};
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::{Query, WorkloadGenerator};

fn queries(n: usize) -> Vec<Query> {
    WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, 42).queries(n)
}

fn run(preset: FleetPreset, options: SimOptions, n: usize, samples: u32) -> SimReport {
    let shape = ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2));
    let mut engine = SimEngine::new(Fleet::preset(preset), shape, options);
    engine.run(&queries(n), samples).unwrap()
}

fn with_calibration(on: bool, drift: DriftPlan) -> SimOptions {
    SimOptions {
        features: OrchestratorFeatures { calibration: on, ..OrchestratorFeatures::full() },
        drift_plan: drift,
        ..Default::default()
    }
}

#[test]
fn zero_drift_is_bit_identical_on_every_preset() {
    // Satellite (a): with no injected drift the calibrated path must
    // never bump the version and must be bit-identical to the
    // uncalibrated path — same energy, same coverage, same plans —
    // on every fleet preset.
    for preset in FleetPreset::all() {
        let r_on = run(preset, with_calibration(true, DriftPlan::none()), 40, 8);
        let r_off = run(preset, with_calibration(false, DriftPlan::none()), 40, 8);
        let trail = r_on.calibration.as_ref().expect("trail present with the feature on");
        assert_eq!(trail.calibration_version, 0, "{preset:?}: version must never bump");
        assert_eq!(trail.energy_table_rebuilds, 0);
        assert_eq!(
            r_on.total_energy_j.to_bits(),
            r_off.total_energy_j.to_bits(),
            "{preset:?}: executed energy must be bit-identical"
        );
        assert_eq!(r_on.coverage.to_bits(), r_off.coverage.to_bits(), "{preset:?}");
        assert_eq!(r_on.plan_energy_j.to_bits(), r_off.plan_energy_j.to_bits(), "{preset:?}");
        assert_eq!(r_on.replans, r_off.replans, "{preset:?}");
        assert_eq!(
            r_on.replan_trail.len(),
            r_off.replan_trail.len(),
            "{preset:?}: same replan episodes"
        );
        for (a, b) in r_on.replan_trail.iter().zip(&r_off.replan_trail) {
            assert_eq!(a.plan, b.plan, "{preset:?}: plans must be bit-identical");
            assert_eq!(a.plan_energy_j.to_bits(), b.plan_energy_j.to_bits());
            assert_eq!(a.calibration_version, 0);
        }
    }
}

#[test]
fn rls_estimate_converges_to_the_injected_derate() {
    // Satellite (b), estimator half: under a ground-truth bandwidth
    // derate the folded overlay must converge to the injected factor.
    // Emulates the engine's loop — predictions always come from the
    // currently applied overlay.
    let mut cal = FleetCalibrator::new(1);
    let nameplate_s = 2.08e-3;
    let true_s = nameplate_s / DERATE_FACTOR;
    let power_w = 7.0;
    for _ in 0..80 {
        let scale = cal.overlay(DevIdx(0)).bandwidth_scale;
        let pred_s = nameplate_s / scale;
        cal.observe_task(DevIdx(0), true, pred_s, true_s, pred_s * power_w, true_s * power_w);
    }
    let est = cal.overlay(DevIdx(0)).bandwidth_scale;
    assert!(
        (est - DERATE_FACTOR).abs() < DERATE_FACTOR * 0.05,
        "bandwidth_scale {est} must land within 5% of {DERATE_FACTOR}"
    );
    assert!(cal.version() >= 1);
}

#[test]
fn derate_replans_on_the_calibration_axis_and_beats_the_stale_plan() {
    // Satellite (b), closed-loop half + the PR acceptance scenario:
    // derate the second decode lane of the edge box. The calibrated
    // run must fold the drift, bump calibration_version in the replan
    // trail (a cache miss on the new key axis — never a stale-plan
    // hit), and finish at strictly lower executed energy than the
    // stale-coefficient run.
    let victim = victim_device(FleetPreset::EdgeBox);
    let drift = || {
        DriftPlan::new(vec![DriftScenario::bandwidth_derate(
            victim.clone(),
            DERATE_AT_S,
            DERATE_FACTOR,
        )])
    };
    let calibrated = run(FleetPreset::EdgeBox, with_calibration(true, drift()), 120, 10);
    let stale = run(FleetPreset::EdgeBox, with_calibration(false, drift()), 120, 10);

    let trail = calibrated.calibration.as_ref().expect("calibration trail");
    assert!(trail.calibration_version >= 1, "the derate must fire the detector");
    assert!(trail.energy_table_rebuilds >= 1);

    // The bump reaches the replan trail as a MISS on the new key axis.
    let bump = calibrated
        .replan_trail
        .iter()
        .find(|ev| ev.calibration_version > 0)
        .expect("a post-drift replan episode must exist");
    assert!(!bump.cache_hit, "the first post-drift replan can never be a stale-plan hit");
    // Calibration versions are monotone along the trail, and the
    // pre-drift episodes all carry version 0.
    for pair in calibrated.replan_trail.windows(2) {
        assert!(pair[0].calibration_version <= pair[1].calibration_version);
    }
    assert_eq!(calibrated.replan_trail[0].calibration_version, 0);

    // Closed loop beats stale coefficients on executed energy.
    assert!(
        calibrated.total_energy_j < stale.total_energy_j,
        "calibrated {} J must strictly beat stale {} J",
        calibrated.total_energy_j,
        stale.total_energy_j
    );
    // Convergence: the recent error sits well below the lifetime mean
    // (which carries the drift spike).
    assert!(trail.recent_abs_energy_err_pct < trail.mean_abs_energy_err_pct);
}

#[test]
fn in_band_contention_noise_never_bumps_the_version() {
    // Zero-mean jitter inside the Page-Hinkley tolerance must never
    // trigger a recalibration (or every noisy query would thrash the
    // plan cache).
    let victim = victim_device(FleetPreset::EdgeBox);
    let drift =
        DriftPlan::new(vec![DriftScenario::contention_noise(victim, 0.0, 0.04)]);
    let r = run(FleetPreset::EdgeBox, with_calibration(true, drift), 60, 8);
    let trail = r.calibration.as_ref().unwrap();
    assert_eq!(trail.calibration_version, 0, "in-band noise must not fold");
    assert_eq!(trail.energy_table_rebuilds, 0);
    assert!(trail.samples > 0);
    for ev in &r.replan_trail {
        assert_eq!(ev.calibration_version, 0);
    }
}

#[test]
fn calibrated_runs_are_bit_deterministic_under_a_fixed_seed() {
    // Satellite (c): the full drift + noise scenario, run twice with
    // the same seed, must agree bit for bit — estimators, detector,
    // noise stream, replan trail, energy.
    let victim = victim_device(FleetPreset::EdgeBox);
    let drift = || {
        DriftPlan::new(vec![
            DriftScenario::bandwidth_derate(victim.clone(), DERATE_AT_S, DERATE_FACTOR),
            DriftScenario::contention_noise(victim.clone(), 0.0, 0.03),
        ])
    };
    let a = run(FleetPreset::EdgeBox, with_calibration(true, drift()), 80, 8);
    let b = run(FleetPreset::EdgeBox, with_calibration(true, drift()), 80, 8);
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    assert_eq!(a.coverage.to_bits(), b.coverage.to_bits());
    assert_eq!(a.replans, b.replans);
    let (ta, tb) = (a.calibration.as_ref().unwrap(), b.calibration.as_ref().unwrap());
    assert_eq!(ta.calibration_version, tb.calibration_version);
    assert_eq!(ta.samples, tb.samples);
    assert_eq!(
        ta.mean_abs_energy_err_pct.to_bits(),
        tb.mean_abs_energy_err_pct.to_bits(),
        "estimator arithmetic must be bit-deterministic"
    );
    assert_eq!(a.replan_trail.len(), b.replan_trail.len());
    for (ea, eb) in a.replan_trail.iter().zip(&b.replan_trail) {
        assert_eq!(ea.plan, eb.plan);
        assert_eq!(ea.plan_energy_j.to_bits(), eb.plan_energy_j.to_bits());
        assert_eq!(ea.calibration_version, eb.calibration_version);
    }
}
