//! Causal-tracing + SLO contract lockdown (PR 10).
//!
//! Span emission and the SLO evaluator are harness state under the
//! same outside-digest rule the PR 9 obs bundle obeys: arming tracing
//! must not move a single bit of any `SimReport`, state digest, or
//! gateway state capture, on any preset (the seven paper presets AND
//! the 100-device metro stress preset), under any schedule mode.
//!
//! The SLO engine's analytic properties are pinned here too: burn rate
//! is monotone in the bad count, the multi-window hysteresis never
//! flaps on a constant stream (at most one transition), and verdicts
//! are a pure function of the sample stream (fixed seed = byte-equal
//! tables). The profile-informed Window-stage divider law
//! (`divider_for_window_rate` / `window_divider_from_profile`) is
//! pinned alongside because its inputs are the deterministic fire
//! counts tracing also rides on.

use qeil::coordinator::allocation::ModelShape;
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::experiments::runner::default_meta;
use qeil::gateway::{Gateway, GatewayConfig, SlaClass};
use qeil::obs::{
    burn_rate, FlightRecorder, SloConfig, SloEvaluator, SloObjective, SloSample, SloVerdict,
};
use qeil::rng::Pcg;
use qeil::sim::engine::{
    divider_for_window_rate, window_divider_from_profile, SimEngine, SimOptions,
    METRO_WINDOW_DIVIDER_MAX, WINDOW_DISPATCH_TARGET_PER_TICK,
};
use qeil::sim::ScheduleMode;
use qeil::snapshot::engine_digest;
use qeil::workload::coverage::CoverageOracle;
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::{Query, WorkloadGenerator};

fn shape() -> ModelShape {
    ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2))
}

fn queries(seed: u64, n: usize) -> Vec<Query> {
    WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, seed).queries(n)
}

fn engine(preset: FleetPreset, options: SimOptions) -> SimEngine {
    SimEngine::new(Fleet::preset(preset), shape(), options)
}

// ---------------------------------------------------------------------
// Trace-on vs trace-off bit-identity, all presets × all schedule modes
// ---------------------------------------------------------------------

#[test]
fn trace_on_and_trace_off_runs_are_bit_identical_on_every_preset() {
    let schedules =
        [ScheduleMode::Legacy, ScheduleMode::Canonical, ScheduleMode::Fuzzed(0xFACADE)];
    for preset in FleetPreset::all() {
        let qs = queries(17, 24);
        for schedule in schedules {
            let options = SimOptions { seed: 17, schedule, ..SimOptions::default() };
            let mut plain = engine(preset, options.clone());
            let mut traced = engine(preset, options);
            traced.enable_trace();
            assert!(traced.obs().spans_enabled());

            let oracle = CoverageOracle::new(plain.seed());
            for q in &qs {
                let a = plain.step_query(q, 4, &oracle);
                let b = traced.step_query(q, 4, &oracle);
                assert_eq!(a, b, "{preset:?}/{schedule:?}: step outcome diverged under tracing");
            }
            let report_plain = plain.finish();
            let report_traced = traced.finish();
            assert_eq!(
                report_traced, report_plain,
                "{preset:?}/{schedule:?}: SimReport moved under tracing"
            );
            assert_eq!(
                engine_digest(&traced),
                engine_digest(&plain),
                "{preset:?}/{schedule:?}: state digest moved under tracing"
            );
            // The traced run actually recorded spans (begin + end per
            // query at minimum) while the plain run recorded nothing.
            let span_events = traced
                .obs()
                .recorder
                .events()
                .iter()
                .filter(|e| e.cat == "trace")
                .count();
            assert!(
                span_events >= 2 * qs.len(),
                "{preset:?}/{schedule:?}: expected span events, got {span_events}"
            );
            assert_eq!(plain.obs().recorder.total_recorded(), 0);
        }
    }
}

#[test]
fn trace_runs_are_bit_identical_on_metro_under_all_schedules() {
    let schedules =
        [ScheduleMode::Legacy, ScheduleMode::Canonical, ScheduleMode::Fuzzed(0xD00D)];
    let qs = queries(31, 8);
    for schedule in schedules {
        let options = SimOptions { seed: 31, schedule, ..SimOptions::default() };
        let mut plain = engine(FleetPreset::Metro, options.clone());
        let mut traced = engine(FleetPreset::Metro, options);
        if !matches!(schedule, ScheduleMode::Legacy) {
            // The production dividers as deployed (Model divider plus
            // the PR 10 profile-informed Window divider) on BOTH
            // replicas — the contract under test is trace-neutrality.
            assert!(plain.apply_default_dividers());
            assert!(traced.apply_default_dividers());
        }
        traced.enable_trace();
        let oracle = CoverageOracle::new(plain.seed());
        for q in &qs {
            let a = plain.step_query(q, 2, &oracle);
            let b = traced.step_query(q, 2, &oracle);
            assert_eq!(a, b, "metro/{schedule:?}: step diverged under tracing");
        }
        assert_eq!(traced.finish(), plain.finish(), "metro/{schedule:?}: report moved");
        assert_eq!(
            engine_digest(&traced),
            engine_digest(&plain),
            "metro/{schedule:?}: digest moved"
        );
        assert!(traced.obs().recorder.events().iter().any(|e| e.cat == "trace"));
    }
}

// ---------------------------------------------------------------------
// Gateway: spans + SLO evaluator live outside the state capture
// ---------------------------------------------------------------------

#[test]
fn gateway_tracing_and_slo_are_outside_the_state_capture() {
    let config = GatewayConfig { tenants: 4, seed: 7, ..GatewayConfig::default() };
    let mut plain = Gateway::new(config.clone());
    let mut armed = Gateway::new(config);
    armed.enable_trace();
    armed.enable_slo(
        vec![
            SloObjective::latency("interactive_p99", SlaClass::Interactive.index(), 0.250, 0.01),
            SloObjective::availability("interactive_avail", SlaClass::Interactive.index(), 0.9),
            SloObjective::thermal_headroom("fleet_headroom", 0.02, 0.5),
            SloObjective::energy_per_query("fleet_energy", 1.0e3, 0.01),
        ],
        SloConfig::default(),
    );

    let trace = plain.overload_trace(180, 3.0, None);
    let report_plain = plain.run_trace(&trace);
    let report_armed = armed.run_trace(&trace);

    assert_eq!(report_armed, report_plain, "gateway report moved under tracing + SLO");
    assert_eq!(
        armed.state_digest(),
        plain.state_digest(),
        "gateway state digest moved under tracing + SLO"
    );

    // The armed gateway produced span events, a critical-path
    // breakdown over every completed request, and SLO verdicts.
    assert!(armed.obs().recorder.events().iter().any(|e| e.cat == "trace"));
    let completed: u64 = SlaClass::all().iter().map(|c| report_armed.class(*c).completed).sum();
    assert!(completed > 0, "overload trace must complete some requests");
    assert_eq!(armed.path().total_requests(), completed);
    let ev = armed.slo().expect("slo evaluator armed");
    assert_eq!(ev.len(), 4);
    let table = ev.render_table();
    assert!(table.contains("interactive_p99"));
    assert!(table.contains("fleet_headroom"));

    // Determinism: a third replica fed the same trace renders the
    // byte-identical verdict table and path table.
    let mut again = Gateway::new(GatewayConfig { tenants: 4, seed: 7, ..GatewayConfig::default() });
    again.enable_trace();
    again.enable_slo(
        vec![
            SloObjective::latency("interactive_p99", SlaClass::Interactive.index(), 0.250, 0.01),
            SloObjective::availability("interactive_avail", SlaClass::Interactive.index(), 0.9),
            SloObjective::thermal_headroom("fleet_headroom", 0.02, 0.5),
            SloObjective::energy_per_query("fleet_energy", 1.0e3, 0.01),
        ],
        SloConfig::default(),
    );
    let report_again = again.run_trace(&trace);
    assert_eq!(report_again, report_armed);
    assert_eq!(again.slo().unwrap().render_table(), table);
    assert_eq!(again.path_table(), armed.path_table());
}

// ---------------------------------------------------------------------
// SLO analytic properties
// ---------------------------------------------------------------------

#[test]
fn burn_rate_is_monotone_in_bad_for_fixed_total_and_budget() {
    for &total in &[1u64, 10, 100, 10_000] {
        for &budget in &[0.001, 0.01, 0.1, 0.5, 1.0] {
            let mut prev = -1.0;
            for bad in 0..=total.min(256) {
                let r = burn_rate(bad, total, budget);
                assert!(
                    r >= prev,
                    "burn_rate not monotone at bad={bad}/{total} budget={budget}"
                );
                prev = r;
            }
            // Endpoints: clean window burns 0, fully-bad window burns
            // 1/budget.
            assert_eq!(burn_rate(0, total, budget), 0.0);
            assert!((burn_rate(total, total, budget) - 1.0 / budget).abs() < 1e-9);
        }
    }
    assert_eq!(burn_rate(0, 0, 0.01), 0.0, "empty window must not alert");
}

#[test]
fn hysteresis_never_flaps_on_constant_streams() {
    // A constant stream — any fixed bad fraction, above or below the
    // budget — may produce at most ONE transition (a single fire, no
    // clear, or nothing at all). Flapping on steady state is the
    // failure mode the two-window + clear-streak design exists to
    // prevent.
    for bad_per_16 in [0u32, 1, 2, 4, 8, 12, 15, 16] {
        let mut ev = SloEvaluator::with_defaults(vec![SloObjective::availability(
            "avail", 0, 0.25,
        )]);
        let mut rec = FlightRecorder::with_capacity(1024);
        for i in 0..4000u32 {
            let shed = (i % 16) < bad_per_16;
            ev.observe(i as f64 * 0.05, SloSample::Outcome { class: 0, shed });
            ev.evaluate(i as f64 * 0.05, &mut rec);
        }
        assert!(
            ev.transitions() <= 1,
            "constant stream ({bad_per_16}/16 bad) flapped: {} transitions",
            ev.transitions()
        );
        // Verdict matches the stream's run-total arithmetic exactly.
        let expect_violated = bad_per_16 as f64 / 16.0 > 0.25;
        assert_eq!(ev.any_violated(), expect_violated, "{bad_per_16}/16 bad");
    }
}

#[test]
fn verdicts_are_deterministic_under_a_fixed_seed() {
    fn run_stream(seed: u64) -> (String, String, u32, bool) {
        let mut ev = SloEvaluator::with_defaults(vec![
            SloObjective::latency("p99", 0, 0.050, 0.01),
            SloObjective::availability("avail", 0, 0.2),
            SloObjective::thermal_headroom("headroom", 0.1, 0.1),
            SloObjective::energy_per_query("energy", 40.0, 0.05),
        ]);
        let mut rec = FlightRecorder::with_capacity(4096);
        let mut rng = Pcg::seeded(seed);
        for i in 0..6000u32 {
            let now = i as f64 * 0.02;
            match rng.below(4) {
                0 => ev.observe(
                    now,
                    SloSample::Latency {
                        class: 0,
                        latency_s: rng.below(100) as f64 * 0.001,
                    },
                ),
                1 => ev.observe(now, SloSample::Outcome { class: 0, shed: rng.below(10) < 3 }),
                2 => ev.observe(
                    now,
                    SloSample::Headroom { value: rng.below(100) as f64 * 0.01 },
                ),
                _ => ev.observe(
                    now,
                    SloSample::Energy { class: 0, joules: rng.below(80) as f64 },
                ),
            }
            if i % 8 == 0 {
                ev.evaluate(now, &mut rec);
            }
        }
        (ev.render_table(), ev.to_json().to_string(), ev.transitions(), ev.any_violated())
    }
    let a = run_stream(0xA11CE);
    let b = run_stream(0xA11CE);
    assert_eq!(a, b, "same seed must give byte-identical verdicts");
    // The stream is adversarial enough to exercise the alert path.
    assert!(a.2 > 0, "expected at least one fire transition");
}

// ---------------------------------------------------------------------
// Profile-informed Window-stage divider law
// ---------------------------------------------------------------------

#[test]
fn window_divider_law_pins() {
    // At or below the per-tick target: divider stays 1.
    for rate in [0u64, 1, 5, WINDOW_DISPATCH_TARGET_PER_TICK] {
        assert_eq!(divider_for_window_rate(rate), 1, "rate {rate}");
    }
    // One doubling covers up to 2× the target.
    for rate in [WINDOW_DISPATCH_TARGET_PER_TICK + 1, 48, 2 * WINDOW_DISPATCH_TARGET_PER_TICK] {
        assert_eq!(divider_for_window_rate(rate), 2, "rate {rate}");
    }
    // Metro (100 devices) needs the full cap; the cap also bounds
    // absurd rates.
    assert_eq!(divider_for_window_rate(100), METRO_WINDOW_DIVIDER_MAX);
    assert_eq!(divider_for_window_rate(u64::MAX), METRO_WINDOW_DIVIDER_MAX);
}

#[test]
fn profile_derived_divider_agrees_with_the_fleet_size_fallback() {
    // A profiled divider-1 metro run observes window fires / execution
    // fires == fleet size, so the profile-informed law lands on the
    // same divider a cold engine derives from the fleet size — the two
    // paths are one deterministic fire-count law.
    let qs = queries(43, 6);
    let options = SimOptions { seed: 43, schedule: ScheduleMode::Canonical, ..SimOptions::default() };
    let mut e = engine(FleetPreset::Metro, options);
    e.enable_obs();
    let oracle = CoverageOracle::new(e.seed());
    for q in &qs {
        e.step_query(q, 2, &oracle);
    }
    e.finish();
    let profiled = window_divider_from_profile(&e.obs().profiler)
        .expect("obs-armed run must yield a profile-derived divider");
    let fallback = divider_for_window_rate(Fleet::preset(FleetPreset::Metro).len() as u64);
    assert_eq!(profiled, fallback, "profiled and fleet-size dividers must agree at divider 1");

    // A cold (never-profiled) engine has no execution fires: the
    // profile path declines and the caller falls back.
    let cold = engine(FleetPreset::Metro, SimOptions::default());
    assert_eq!(window_divider_from_profile(&cold.obs().profiler), None);
}
