//! Property-based tests over coordinator + safety invariants (seeded
//! random cases via `qeil::testing::check`; no artifacts needed).

use qeil::coordinator::allocation::{Allocation, ModelShape};
use qeil::coordinator::batcher::Batcher;
use qeil::coordinator::exact::optimal_assignment;
use qeil::coordinator::orchestrator::Orchestrator;
use qeil::coordinator::pgsam::PgsamConfig;
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::devices::spec::DeviceId;
use qeil::devices::thermal::ThermalState;
use qeil::prop_assert;
use qeil::runtime::manifest::VariantMeta;
use qeil::safety::ratelimit::RateLimiter;
use qeil::safety::thermal_guard::ThermalGuard;
use qeil::testing::check;
use qeil::workload::datasets::ModelFamily;

fn meta(layers: usize) -> VariantMeta {
    VariantMeta {
        name: "x".into(),
        vocab: 512,
        d_model: 64,
        n_layers: layers,
        n_heads: 4,
        head_dim: 16,
        d_ff: 256,
        max_seq: 64,
        prefill_len: 32,
        paper_params: 125_000_000,
        variant_params: 268_672,
        flops_prefill: 1,
        flops_per_token_decode: 1,
        bytes_per_token_decode: 1,
        cache_shape: [4, 4, 64, 16],
        prefill_artifact: "p".into(),
        decode_artifact: "d".into(),
            decode_chunk_artifact: None,
            decode_chunk: 0,
    }
}

fn random_family(rng: &mut qeil::rng::Pcg) -> ModelFamily {
    let all = ModelFamily::all();
    all[rng.below(all.len() as u64) as usize]
}

#[test]
fn prop_greedy_assignment_never_violates_memory() {
    check("greedy memory safety", 200, |rng| {
        let family = random_family(rng);
        let layers = 1 + rng.below(16) as usize;
        let shape = ModelShape::from_family(family, &meta(layers));
        let presets =
            [FleetPreset::EdgeBox, FleetPreset::MultiVendor, FleetPreset::NpuOnly, FleetPreset::CpuOnly];
        let fleet = Fleet::preset(presets[rng.below(4) as usize]);
        let orch = Orchestrator::new(&fleet);
        match orch.assign(&shape) {
            Ok(alloc) => {
                prop_assert!(
                    alloc.check_memory(&shape, &fleet).is_ok(),
                    "memory violated for {family:?} L={layers}"
                );
                prop_assert!(alloc.layers.len() == layers, "layer count mismatch");
                Ok(())
            }
            Err(_) => Ok(()), // infeasible is a legal outcome
        }
    });
}

#[test]
fn prop_pgsam_never_worse_than_greedy_and_memory_safe() {
    // PGSAM refines the greedy seed and only ever keeps improvements, so
    // its energy is bounded by greedy's and every plan it returns passes
    // the Eq. 12 memory constraints — on every fleet preset.
    check("pgsam dominates greedy", 60, |rng| {
        let family = random_family(rng);
        let layers = 1 + rng.below(16) as usize;
        let shape = ModelShape::from_family(family, &meta(layers));
        let presets = [
            FleetPreset::EdgeBox,
            FleetPreset::MultiVendor,
            FleetPreset::NpuOnly,
            FleetPreset::CpuOnly,
            FleetPreset::GpuOnly,
            FleetPreset::IgpuOnly,
            FleetPreset::Cloud,
        ];
        let fleet = Fleet::preset(presets[rng.below(presets.len() as u64) as usize]);
        let orch = Orchestrator::new(&fleet);
        let cfg = PgsamConfig::default().with_seed(rng.next_u64());
        match (orch.assign(&shape), orch.assign_pgsam(&shape, &cfg)) {
            (Ok(greedy), Ok((alloc, e))) => {
                let greedy_e = orch.allocation_energy_j(&shape, &greedy);
                prop_assert!(
                    e <= greedy_e * (1.0 + 1e-9),
                    "{family:?} L={layers}: pgsam {e} > greedy {greedy_e}"
                );
                prop_assert!(
                    alloc.check_memory(&shape, &fleet).is_ok(),
                    "{family:?} L={layers}: pgsam plan violates memory"
                );
                prop_assert!(alloc.layers.len() == layers, "layer count mismatch");
                // Reported energy is the exact objective value.
                let recomputed = orch.allocation_energy_j(&shape, &alloc);
                prop_assert!(
                    (recomputed - e).abs() <= 1e-9 * e.max(1.0),
                    "energy report drifted: {e} vs {recomputed}"
                );
                Ok(())
            }
            (Err(_), Err(_)) => Ok(()), // infeasible is a legal outcome
            (g, p) => Err(format!(
                "planners disagree on feasibility: greedy {:?}, pgsam {:?}",
                g.is_ok(),
                p.is_ok()
            )),
        }
    });
}

#[test]
fn prop_pgsam_deterministic_under_fixed_seed() {
    check("pgsam determinism", 25, |rng| {
        let family = random_family(rng);
        let layers = 2 + rng.below(10) as usize;
        let shape = ModelShape::from_family(family, &meta(layers));
        let fleet = Fleet::preset(if rng.chance(0.5) {
            FleetPreset::EdgeBox
        } else {
            FleetPreset::MultiVendor
        });
        let orch = Orchestrator::new(&fleet);
        let cfg = PgsamConfig::default().with_seed(rng.next_u64());
        let (Ok((a, ea)), Ok((b, eb))) =
            (orch.assign_pgsam(&shape, &cfg), orch.assign_pgsam(&shape, &cfg))
        else {
            return Ok(()); // infeasible is a legal outcome
        };
        prop_assert!(a.embedding == b.embedding, "embedding differs across runs");
        prop_assert!(a.layers == b.layers, "layer plan differs across runs");
        prop_assert!(a.lm_head == b.lm_head, "lm_head differs across runs");
        prop_assert!(ea == eb, "energy differs across runs: {ea} vs {eb}");
        Ok(())
    });
}

#[test]
fn prop_pgsam_warm_restart_deterministic_and_never_worse_than_cold() {
    // The plan-cache warm-restart contract, across all presets and
    // seeds: seeding PGSAM with the Pareto archive of a cold anneal of
    // the same key (the anneal self-reduces to the eighth warm budget
    // when a feasible point engages) (a) is deterministic, (b) never
    // yields higher energy than the cold anneal — the archive contains
    // the cold winner, which floors the warm walk — and (c) still
    // respects memory on every fleet preset. After a device failure
    // (new health signature), the stale archive is filtered and the
    // warm result keeps PGSAM's standing never-worse-than-greedy floor
    // (full cold budget when nothing feasible survives).
    check("pgsam warm restart", 40, |rng| {
        let family = random_family(rng);
        let layers = 1 + rng.below(16) as usize;
        let shape = ModelShape::from_family(family, &meta(layers));
        let presets = [
            FleetPreset::EdgeBox,
            FleetPreset::MultiVendor,
            FleetPreset::NpuOnly,
            FleetPreset::CpuOnly,
            FleetPreset::GpuOnly,
            FleetPreset::IgpuOnly,
            FleetPreset::Cloud,
        ];
        let fleet = Fleet::preset(presets[rng.below(presets.len() as u64) as usize]);
        let orch = Orchestrator::new(&fleet);
        let cfg = PgsamConfig::default().with_seed(rng.next_u64());
        let Ok(cold) = orch.pgsam_outcome(&shape, &cfg) else {
            return Ok(()); // infeasible is a legal outcome
        };
        let Ok(warm) = orch.pgsam_outcome_warm(&shape, &cfg, &cold.archive) else {
            return Err("warm restart must be feasible when cold is".to_string());
        };
        prop_assert!(
            warm.energy_j <= cold.energy_j * (1.0 + 1e-9),
            "warm {} > cold {}",
            warm.energy_j,
            cold.energy_j
        );
        let alloc = Allocation::from_indices(&fleet, &warm.plan);
        prop_assert!(
            alloc.check_memory(&shape, &fleet).is_ok(),
            "warm plan violates memory"
        );
        let Ok(again) = orch.pgsam_outcome_warm(&shape, &cfg, &cold.archive) else {
            return Err("warm restart must be reproducible".to_string());
        };
        prop_assert!(again.plan == warm.plan, "warm restart is not deterministic");
        prop_assert!(
            again.energy_j.to_bits() == warm.energy_j.to_bits(),
            "warm restart energy not bit-reproducible"
        );

        // Degraded fleet: the healthy archive is a stale hint — the
        // greedy floor must still hold and no excluded device may
        // appear in the plan.
        if fleet.len() >= 2 {
            let excluded = fleet.devices()[rng.below(fleet.len() as u64) as usize].id.clone();
            let mut degraded = Orchestrator::new(&fleet);
            degraded.exclude(&excluded);
            match (degraded.assign(&shape), degraded.pgsam_outcome_warm(&shape, &cfg, &cold.archive)) {
                (Ok(greedy), Ok(w)) => {
                    let greedy_e = degraded.allocation_energy_j(&shape, &greedy);
                    prop_assert!(
                        w.energy_j <= greedy_e * (1.0 + 1e-9),
                        "degraded warm {} > greedy {greedy_e}",
                        w.energy_j
                    );
                    prop_assert!(
                        w.plan.iter().all(|&d| fleet.id_at(d) != &excluded),
                        "warm plan uses the excluded device"
                    );
                    let w_alloc = Allocation::from_indices(&fleet, &w.plan);
                    prop_assert!(
                        w_alloc.check_memory(&shape, &fleet).is_ok(),
                        "degraded warm plan violates memory"
                    );
                }
                (Err(_), Err(_)) => {} // both infeasible: legal
                (g, w) => {
                    return Err(format!(
                        "degraded feasibility disagreement: greedy {:?}, warm {:?}",
                        g.is_ok(),
                        w.is_ok()
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_apportionment_prefix_stable_and_monotone() {
    // The SLA-deadline accounting depends on this (ROADMAP sharp edge):
    // the weighted batcher's divisor sequence must assign the first n
    // samples identically under every larger total, making per-device
    // shares componentwise monotone in the sample count.
    use qeil::coordinator::batcher::Batch;
    check("apportionment stability", 120, |rng| {
        let n_devices = 1 + rng.below(6) as usize;
        let devices: Vec<DeviceId> =
            (0..n_devices).map(|i| DeviceId(format!("d{i}"))).collect();
        let rates: Vec<f64> = (0..n_devices).map(|_| rng.range_f64(0.05, 8.0)).collect();
        let n_max = 1 + rng.below(60) as u32;
        let batcher = Batcher { max_batch: 1 + rng.below(16) as usize };
        let owner_of = |batches: &[Batch], n: u32| -> Vec<usize> {
            let mut owner = vec![usize::MAX; n as usize];
            for batch in batches {
                let di = devices.iter().position(|d| d == &batch.device).unwrap();
                for &s in &batch.samples {
                    owner[s as usize] = di;
                }
            }
            owner
        };
        let full = owner_of(&batcher.assign_weighted(n_max, &devices, &rates), n_max);
        prop_assert!(full.iter().all(|&d| d != usize::MAX), "unassigned sample at full draw");
        for n in 0..n_max {
            let owner = owner_of(&batcher.assign_weighted(n, &devices, &rates), n);
            prop_assert!(
                owner[..] == full[..n as usize],
                "draw {n} is not a prefix of draw {n_max}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_pgsam_within_five_percent_of_optimal_on_small_spaces() {
    // On exhaustively checkable (L·D) spaces, PGSAM inherits the greedy
    // seed's §3.7 bound and may only tighten it.
    check("pgsam near-optimality", 12, |rng| {
        let families = [ModelFamily::Gpt2, ModelFamily::Granite, ModelFamily::Qwen2];
        let family = families[rng.below(3) as usize];
        let layers = 2 + rng.below(4) as usize; // 4..=7 stages on 4 devices
        let shape = ModelShape::from_family(family, &meta(layers));
        let fleet = Fleet::preset(FleetPreset::EdgeBox);
        let orch = Orchestrator::new(&fleet);
        let cfg = PgsamConfig::default().with_seed(rng.next_u64());
        let Ok((_, pgsam_e)) = orch.assign_pgsam(&shape, &cfg) else {
            return Err("edge box must be feasible for small shapes".to_string());
        };
        let Some((_, opt_e)) = optimal_assignment(&shape, &fleet, 50_000_000) else {
            return Err("search space unexpectedly large".to_string());
        };
        prop_assert!(
            pgsam_e >= opt_e - 1e-9 * opt_e.abs(),
            "{family:?} L={layers}: pgsam {pgsam_e} beat the exact optimum {opt_e}"
        );
        let gap = (pgsam_e - opt_e) / opt_e;
        prop_assert!(gap <= 0.05, "{family:?} L={layers}: gap {gap} > 5%");
        Ok(())
    });
}

#[test]
fn golden_energy_table_matches_direct_power_model_on_all_presets() {
    // Golden regression pin for the PR 1 memoization substrate: every
    // cached `(stage kind, device)` energy/seconds entry must equal the
    // direct PowerModel/roofline computation BIT FOR BIT, on every fleet
    // preset and a spread of model shapes — so future planner refactors
    // cannot silently drift the memoized values.
    use qeil::coordinator::energy_table::{EnergyTable, StageKind, TRANSFER_J_PER_BYTE};
    use qeil::devices::power::PowerModel;
    use qeil::devices::roofline::{Phase, Task};
    use qeil::devices::spec::DevIdx;

    for preset in FleetPreset::all() {
        let fleet = Fleet::preset(preset);
        for family in ModelFamily::all() {
            for layers in [1usize, 4, 10] {
                let shape = ModelShape::from_family(family, &meta(layers));
                let table = EnergyTable::build(&fleet, &shape);
                assert_eq!(table.n_devices(), fleet.len());
                assert_eq!(table.n_layers(), layers);
                assert_eq!(table.n_stages(), layers + 2);
                let kinds = [
                    (StageKind::Embedding, &shape.embedding),
                    (StageKind::Layer, &shape.per_layer),
                    (StageKind::LmHead, &shape.lm_head),
                ];
                for (kind, cost) in kinds {
                    // The exact task the table builder evaluates.
                    let task = Task {
                        phase: Phase::Decode,
                        flops: cost.flops,
                        bytes: cost.bytes,
                        mem_gb: cost.mem_gb,
                        launches: 1,
                    };
                    assert_eq!(
                        table.mem_gb(kind).to_bits(),
                        cost.mem_gb.to_bits(),
                        "{preset:?}/{family:?}/L{layers}: stage memory drifted"
                    );
                    for (i, spec) in fleet.devices().iter().enumerate() {
                        let idx = DevIdx(i as u16);
                        let direct_e = PowerModel::energy_for(spec, &task, 1.0);
                        let direct_s = task.seconds_on(spec, 1.0);
                        assert_eq!(
                            table.energy(kind, idx).to_bits(),
                            direct_e.to_bits(),
                            "{preset:?}/{family:?}/L{layers}/{}: energy({kind:?}) drifted: \
                             cached {} vs direct {direct_e}",
                            spec.id,
                            table.energy(kind, idx)
                        );
                        assert_eq!(
                            table.seconds(kind, idx).to_bits(),
                            direct_s.to_bits(),
                            "{preset:?}/{family:?}/L{layers}/{}: seconds({kind:?}) drifted",
                            spec.id
                        );
                        assert_eq!(
                            table.capacity_gb(idx).to_bits(),
                            spec.mem_gb.to_bits(),
                            "{preset:?}/{family:?}: capacity drifted for {}",
                            spec.id
                        );
                    }
                }
                // Boundary-crossing energy is the shape's activation
                // bytes at the fixed interconnect figure.
                assert_eq!(
                    table.transfer_j().to_bits(),
                    (shape.boundary_bytes * TRANSFER_J_PER_BYTE).to_bits(),
                    "{preset:?}/{family:?}/L{layers}: transfer energy drifted"
                );
                // And a single-device plan's full-sweep energy is the
                // exact stage sum (no crossings).
                let plan = vec![DevIdx(0); layers + 2];
                let expect = table.energy(StageKind::Embedding, DevIdx(0))
                    + layers as f64 * table.energy(StageKind::Layer, DevIdx(0))
                    + table.energy(StageKind::LmHead, DevIdx(0));
                let swept = table.plan_energy_j(&plan);
                assert!(
                    (swept - expect).abs() <= 1e-12 * expect.abs().max(1.0),
                    "{preset:?}/{family:?}/L{layers}: plan sweep {swept} vs stage sum {expect}"
                );
            }
        }
    }
}

#[test]
fn prop_batcher_conserves_samples() {
    check("batcher conservation", 300, |rng| {
        let n_samples = rng.below(200) as u32;
        let n_devices = 1 + rng.below(6) as usize;
        let max_batch = 1 + rng.below(16) as usize;
        let devices: Vec<DeviceId> =
            (0..n_devices).map(|i| DeviceId(format!("d{i}"))).collect();
        let batches = Batcher { max_batch }.assign(n_samples, &devices);
        let mut seen: Vec<u32> = batches.iter().flat_map(|b| b.samples.clone()).collect();
        seen.sort_unstable();
        let expect: Vec<u32> = (0..n_samples).collect();
        prop_assert!(seen == expect, "samples lost or duplicated: {} vs {}", seen.len(), n_samples);
        for b in &batches {
            prop_assert!(b.samples.len() <= max_batch, "batch over cap");
        }
        Ok(())
    });
}

#[test]
fn prop_thermal_guard_keeps_any_device_below_limit() {
    check("guard bounds temperature", 40, |rng| {
        let specs = [
            qeil::devices::spec::DeviceSpec::intel_cpu(),
            qeil::devices::spec::DeviceSpec::intel_npu(),
            qeil::devices::spec::DeviceSpec::intel_igpu(),
            qeil::devices::spec::DeviceSpec::nvidia_gpu(),
            qeil::devices::spec::DeviceSpec::cloud_gpu(),
        ];
        let spec = specs[rng.below(5) as usize].clone();
        let guard = ThermalGuard::default();
        let mut thermal = ThermalState::new(&spec);
        // Random offered load pattern, guard-modulated.
        for _ in 0..20_000 {
            let offered = rng.range_f64(0.2, 1.0);
            let decision = guard.evaluate(&spec, thermal.temp_c());
            let factor = offered.min(decision.workload_factor);
            let power = spec.idle_w + (spec.tdp_w - spec.idle_w) * factor;
            thermal.step(&spec, power, 0.1);
            prop_assert!(
                thermal.temp_c() <= spec.t_max_c + 1e-6,
                "{}: temp {} exceeded T_max",
                spec.id,
                thermal.temp_c()
            );
        }
        prop_assert!(thermal.throttle_events() == 0, "{}: hw throttled", spec.id);
        Ok(())
    });
}

#[test]
fn prop_rate_limiter_never_exceeds_sustained_rate() {
    check("rate limiter sustained bound", 100, |rng| {
        let rate = rng.range_f64(1.0, 50.0);
        let burst = rng.range_f64(1.0, 20.0);
        let mut rl = RateLimiter::new(rate, burst);
        let horizon_s = 20.0;
        let offered = rate * rng.range_f64(2.0, 10.0); // heavy overload
        let n = (offered * horizon_s) as u64;
        let mut admitted = 0u64;
        for i in 0..n {
            let t = i as f64 / offered;
            if rl.admit(0, t) {
                admitted += 1;
            }
        }
        let bound = (rate * horizon_s + burst).ceil() as u64 + 1;
        prop_assert!(admitted <= bound, "admitted {admitted} > bound {bound}");
        Ok(())
    });
}

#[test]
fn prop_coverage_oracle_monotone_in_budget() {
    use qeil::workload::coverage::CoverageOracle;
    use qeil::workload::datasets::Dataset;
    use qeil::workload::generator::WorkloadGenerator;
    check("coverage monotone", 30, |rng| {
        let seed = rng.next_u64();
        let family = random_family(rng);
        let gen = WorkloadGenerator::new(Dataset::WikiText103, family, seed);
        let queries = gen.queries(150);
        let oracle = CoverageOracle::new(seed ^ 0xABCD);
        let mut prev = -1.0;
        for s in [1u32, 2, 5, 10, 20] {
            let c = oracle.coverage(&queries, s);
            prop_assert!(c >= prev, "coverage decreased at S={s}: {c} < {prev}");
            prev = c;
        }
        Ok(())
    });
}

#[test]
fn prop_sim_energy_breakdown_always_sums() {
    use qeil::config::{ExecMode, OrchestratorFeatures};
    use qeil::sim::engine::{SimEngine, SimOptions};
    use qeil::workload::datasets::Dataset;
    use qeil::workload::generator::WorkloadGenerator;
    check("sim energy additivity", 20, |rng| {
        let family = random_family(rng);
        let shape = ModelShape::from_family(family, &meta(4));
        let hetero = rng.chance(0.5);
        let options = SimOptions {
            mode: if hetero { ExecMode::EnergyAware } else { ExecMode::Standard },
            features: if hetero {
                OrchestratorFeatures::full()
            } else {
                OrchestratorFeatures::baseline()
            },
            seed: rng.next_u64(),
            ..Default::default()
        };
        let fleet = Fleet::preset(if hetero { FleetPreset::EdgeBox } else { FleetPreset::GpuOnly });
        let mut engine = SimEngine::new(fleet, shape, options);
        let queries = WorkloadGenerator::new(Dataset::Gsm8k, family, rng.next_u64()).queries(20);
        let r = engine.run(&queries, 5).unwrap();
        let parts = r.prefill_energy_j + r.decode_energy_j + r.overhead_energy_j;
        prop_assert!(
            (parts - r.total_energy_j).abs() <= 1e-6 * r.total_energy_j.max(1.0),
            "breakdown {parts} != total {}",
            r.total_energy_j
        );
        prop_assert!(r.queries_lost == 0, "no failures injected, none may be lost");
        Ok(())
    });
}
