//! Property-based tests over coordinator + safety invariants (seeded
//! random cases via `qeil::testing::check`; no artifacts needed).

use qeil::coordinator::allocation::ModelShape;
use qeil::coordinator::batcher::Batcher;
use qeil::coordinator::orchestrator::Orchestrator;
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::devices::spec::DeviceId;
use qeil::devices::thermal::ThermalState;
use qeil::prop_assert;
use qeil::runtime::manifest::VariantMeta;
use qeil::safety::ratelimit::RateLimiter;
use qeil::safety::thermal_guard::ThermalGuard;
use qeil::testing::check;
use qeil::workload::datasets::ModelFamily;

fn meta(layers: usize) -> VariantMeta {
    VariantMeta {
        name: "x".into(),
        vocab: 512,
        d_model: 64,
        n_layers: layers,
        n_heads: 4,
        head_dim: 16,
        d_ff: 256,
        max_seq: 64,
        prefill_len: 32,
        paper_params: 125_000_000,
        variant_params: 268_672,
        flops_prefill: 1,
        flops_per_token_decode: 1,
        bytes_per_token_decode: 1,
        cache_shape: [4, 4, 64, 16],
        prefill_artifact: "p".into(),
        decode_artifact: "d".into(),
            decode_chunk_artifact: None,
            decode_chunk: 0,
    }
}

fn random_family(rng: &mut qeil::rng::Pcg) -> ModelFamily {
    let all = ModelFamily::all();
    all[rng.below(all.len() as u64) as usize]
}

#[test]
fn prop_greedy_assignment_never_violates_memory() {
    check("greedy memory safety", 200, |rng| {
        let family = random_family(rng);
        let layers = 1 + rng.below(16) as usize;
        let shape = ModelShape::from_family(family, &meta(layers));
        let presets =
            [FleetPreset::EdgeBox, FleetPreset::MultiVendor, FleetPreset::NpuOnly, FleetPreset::CpuOnly];
        let fleet = Fleet::preset(presets[rng.below(4) as usize]);
        let orch = Orchestrator::new(&fleet);
        match orch.assign(&shape) {
            Ok(alloc) => {
                prop_assert!(
                    alloc.check_memory(&shape, &fleet).is_ok(),
                    "memory violated for {family:?} L={layers}"
                );
                prop_assert!(alloc.layers.len() == layers, "layer count mismatch");
                Ok(())
            }
            Err(_) => Ok(()), // infeasible is a legal outcome
        }
    });
}

#[test]
fn prop_batcher_conserves_samples() {
    check("batcher conservation", 300, |rng| {
        let n_samples = rng.below(200) as u32;
        let n_devices = 1 + rng.below(6) as usize;
        let max_batch = 1 + rng.below(16) as usize;
        let devices: Vec<DeviceId> =
            (0..n_devices).map(|i| DeviceId(format!("d{i}"))).collect();
        let batches = Batcher { max_batch }.assign(n_samples, &devices);
        let mut seen: Vec<u32> = batches.iter().flat_map(|b| b.samples.clone()).collect();
        seen.sort_unstable();
        let expect: Vec<u32> = (0..n_samples).collect();
        prop_assert!(seen == expect, "samples lost or duplicated: {} vs {}", seen.len(), n_samples);
        for b in &batches {
            prop_assert!(b.samples.len() <= max_batch, "batch over cap");
        }
        Ok(())
    });
}

#[test]
fn prop_thermal_guard_keeps_any_device_below_limit() {
    check("guard bounds temperature", 40, |rng| {
        let specs = [
            qeil::devices::spec::DeviceSpec::intel_cpu(),
            qeil::devices::spec::DeviceSpec::intel_npu(),
            qeil::devices::spec::DeviceSpec::intel_igpu(),
            qeil::devices::spec::DeviceSpec::nvidia_gpu(),
            qeil::devices::spec::DeviceSpec::cloud_gpu(),
        ];
        let spec = specs[rng.below(5) as usize].clone();
        let guard = ThermalGuard::default();
        let mut thermal = ThermalState::new(&spec);
        // Random offered load pattern, guard-modulated.
        for _ in 0..20_000 {
            let offered = rng.range_f64(0.2, 1.0);
            let decision = guard.evaluate(&spec, thermal.temp_c());
            let factor = offered.min(decision.workload_factor);
            let power = spec.idle_w + (spec.tdp_w - spec.idle_w) * factor;
            thermal.step(&spec, power, 0.1);
            prop_assert!(
                thermal.temp_c() <= spec.t_max_c + 1e-6,
                "{}: temp {} exceeded T_max",
                spec.id,
                thermal.temp_c()
            );
        }
        prop_assert!(thermal.throttle_events() == 0, "{}: hw throttled", spec.id);
        Ok(())
    });
}

#[test]
fn prop_rate_limiter_never_exceeds_sustained_rate() {
    check("rate limiter sustained bound", 100, |rng| {
        let rate = rng.range_f64(1.0, 50.0);
        let burst = rng.range_f64(1.0, 20.0);
        let mut rl = RateLimiter::new(rate, burst);
        let horizon_s = 20.0;
        let offered = rate * rng.range_f64(2.0, 10.0); // heavy overload
        let n = (offered * horizon_s) as u64;
        let mut admitted = 0u64;
        for i in 0..n {
            let t = i as f64 / offered;
            if rl.admit(0, t) {
                admitted += 1;
            }
        }
        let bound = (rate * horizon_s + burst).ceil() as u64 + 1;
        prop_assert!(admitted <= bound, "admitted {admitted} > bound {bound}");
        Ok(())
    });
}

#[test]
fn prop_coverage_oracle_monotone_in_budget() {
    use qeil::workload::coverage::CoverageOracle;
    use qeil::workload::datasets::Dataset;
    use qeil::workload::generator::WorkloadGenerator;
    check("coverage monotone", 30, |rng| {
        let seed = rng.next_u64();
        let family = random_family(rng);
        let gen = WorkloadGenerator::new(Dataset::WikiText103, family, seed);
        let queries = gen.queries(150);
        let oracle = CoverageOracle::new(seed ^ 0xABCD);
        let mut prev = -1.0;
        for s in [1u32, 2, 5, 10, 20] {
            let c = oracle.coverage(&queries, s);
            prop_assert!(c >= prev, "coverage decreased at S={s}: {c} < {prev}");
            prev = c;
        }
        Ok(())
    });
}

#[test]
fn prop_sim_energy_breakdown_always_sums() {
    use qeil::config::{ExecMode, OrchestratorFeatures};
    use qeil::sim::engine::{SimEngine, SimOptions};
    use qeil::workload::datasets::Dataset;
    use qeil::workload::generator::WorkloadGenerator;
    check("sim energy additivity", 20, |rng| {
        let family = random_family(rng);
        let shape = ModelShape::from_family(family, &meta(4));
        let hetero = rng.chance(0.5);
        let options = SimOptions {
            mode: if hetero { ExecMode::EnergyAware } else { ExecMode::Standard },
            features: if hetero {
                OrchestratorFeatures::full()
            } else {
                OrchestratorFeatures::baseline()
            },
            seed: rng.next_u64(),
            ..Default::default()
        };
        let fleet = Fleet::preset(if hetero { FleetPreset::EdgeBox } else { FleetPreset::GpuOnly });
        let mut engine = SimEngine::new(fleet, shape, options);
        let queries = WorkloadGenerator::new(Dataset::Gsm8k, family, rng.next_u64()).queries(20);
        let r = engine.run(&queries, 5).unwrap();
        let parts = r.prefill_energy_j + r.decode_energy_j + r.overhead_energy_j;
        prop_assert!(
            (parts - r.total_energy_j).abs() <= 1e-6 * r.total_energy_j.max(1.0),
            "breakdown {parts} != total {}",
            r.total_energy_j
        );
        prop_assert!(r.queries_lost == 0, "no failures injected, none may be lost");
        Ok(())
    });
}
