//! Snapshot/replay failover substrate — integration lockdown.
//!
//! Contracts under test (ISSUE 6 / ROADMAP "snapshot & replay"):
//!   * serialize → restore → re-serialize is BYTE-identical, and a
//!     restored engine behaves bit-identically to the original from
//!     that point on (restart determinism: no iteration-order or
//!     hidden-state leak survives a process boundary);
//!   * a ≥100k-token soak with injected faults, coefficient drift, and
//!     contention noise produces a bit-identical `SimReport` + state
//!     digest whether run straight or chopped through checkpoint/
//!     restore cycles, on an edge and a datacenter preset;
//!   * the crash-recovery drill matrix (kill at pinned + per-seed
//!     fuzzed ticks, restore last checkpoint, replay the log suffix)
//!     passes bit-exactly on EVERY fleet preset;
//!   * the desync detector localizes a stale-coefficient replica to an
//!     exact first-divergence tick and names the diverging component;
//!   * historical snapshots forward-migrate on restore and land on the
//!     same digest: v1 (no `clock.pjrt_time_scale`) and v2 (no `des`
//!     discrete-event scheduler component) both walk to the current
//!     format;
//!   * the metro fleet-scale preset (100 devices) survives the
//!     kill/restore/replay drill bit-exactly, under the canonical AND
//!     a fuzzed same-tick dispatch schedule.

use qeil::calibration::drift::{DriftPlan, DriftScenario};
use qeil::calibration::CalibratedSpec;
use qeil::coordinator::allocation::ModelShape;
use qeil::devices::failure::{FailureKind, FailurePlan, FailureScenario};
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::devices::spec::DevIdx;
use qeil::experiments::runner::default_meta;
use qeil::json::Json;
use qeil::sim::engine::{SimEngine, SimOptions, SimReport};
use qeil::sim::ScheduleMode;
use qeil::snapshot::desync::{detect_desync, stale_replica};
use qeil::snapshot::drill::{drill_all_presets, drill_preset};
use qeil::snapshot::replay::{EventLog, ReplaySession};
use qeil::snapshot::{engine_digest, restore_engine, snapshot_engine};
use qeil::workload::coverage::CoverageOracle;
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::{Query, WorkloadGenerator};

fn shape() -> ModelShape {
    ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2))
}

fn queries(dataset: Dataset, seed: u64, n: usize) -> Vec<Query> {
    WorkloadGenerator::new(dataset, ModelFamily::Gpt2, seed).queries(n)
}

fn engine(preset: FleetPreset, options: SimOptions) -> SimEngine {
    SimEngine::new(Fleet::preset(preset), shape(), options)
}

/// Serialize → string → parse → restore: the process boundary every
/// test crosses. Nothing but bytes survives.
fn round_trip(e: &SimEngine) -> SimEngine {
    let text = snapshot_engine(e).to_string();
    restore_engine(&Json::parse(&text).unwrap()).unwrap()
}

// ---------------------------------------------------------------------
// Restart determinism
// ---------------------------------------------------------------------

#[test]
fn restore_is_byte_identical_and_behaviorally_transparent() {
    let qs = queries(Dataset::WikiText103, 3, 40);
    let mut warm = engine(FleetPreset::EdgeBox, SimOptions { seed: 3, ..SimOptions::default() });
    let oracle = CoverageOracle::new(warm.seed());
    for q in &qs[..30] {
        warm.step_query(q, 4, &oracle);
    }

    // Byte identity: serializing the restored engine reproduces the
    // exact snapshot text — any nondeterministic iteration order (a
    // HashMap somewhere in engine state) or lossy field codec would
    // break this immediately.
    let text = snapshot_engine(&warm).to_string();
    let restored = restore_engine(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(snapshot_engine(&restored).to_string(), text);
    assert_eq!(engine_digest(&restored), engine_digest(&warm));

    // Behavioral transparency: from the restore point on, the restored
    // engine and the uninterrupted one must take bit-identical steps.
    let mut warm = warm;
    let mut restored = restored;
    for q in &qs[30..] {
        let a = warm.step_query(q, 4, &oracle);
        let b = restored.step_query(q, 4, &oracle);
        assert_eq!(a, b);
        assert_eq!(engine_digest(&restored), engine_digest(&warm));
    }
    assert_eq!(restored.finish(), warm.finish());
}

#[test]
fn double_round_trip_is_stable() {
    let qs = queries(Dataset::WikiText103, 9, 25);
    let mut e = engine(FleetPreset::EdgeBox, SimOptions { seed: 9, ..SimOptions::default() });
    let oracle = CoverageOracle::new(e.seed());
    for q in &qs {
        e.step_query(q, 4, &oracle);
    }
    let once = round_trip(&e);
    let twice = round_trip(&once);
    assert_eq!(
        snapshot_engine(&twice).to_string(),
        snapshot_engine(&e).to_string()
    );
}

// ---------------------------------------------------------------------
// Logical-clock soak: straight vs chunked through restore cycles
// ---------------------------------------------------------------------

/// Faults + drift + contention noise targeted at the devices the given
/// preset actually has; the noise scenario forces mid-stream draws from
/// the engine's noise RNG, so a restore that mis-carried RNG state
/// would diverge within a few ticks.
fn soak_options(seed: u64, fault_device: &str, drift_device: &str) -> SimOptions {
    SimOptions {
        seed,
        failure_plan: FailurePlan::new(vec![
            FailureScenario {
                device: fault_device.into(),
                kind: FailureKind::Crash,
                at_s: 5.0,
                recover_after_s: Some(10.0),
            },
            FailureScenario {
                device: fault_device.into(),
                kind: FailureKind::ErrorRate(0.05),
                at_s: 40.0,
                recover_after_s: None,
            },
        ]),
        drift_plan: DriftPlan::new(vec![
            DriftScenario::bandwidth_derate(drift_device.into(), 10.0, 0.5),
            DriftScenario::contention_noise(drift_device.into(), 1.0, 0.05),
            DriftScenario::idle_creep(drift_device.into(), 25.0, 1.3),
        ]),
        ..SimOptions::default()
    }
}

fn run_straight(
    preset: FleetPreset,
    options: SimOptions,
    log: &EventLog,
) -> (SimReport, u64) {
    let mut session = ReplaySession::new(engine(preset, options), log.clone()).unwrap();
    let report = session.run_to_end();
    let digest = engine_digest(session.engine());
    (report, digest)
}

/// Same run chopped at `cuts`: at each cut the live engine is dropped
/// and the run continues from a string-round-tripped snapshot plus the
/// log — N full checkpoint/restore cycles inside one logical clock.
fn run_chunked(
    preset: FleetPreset,
    options: SimOptions,
    log: &EventLog,
    cuts: &[u64],
) -> (SimReport, u64) {
    let mut session = ReplaySession::new(engine(preset, options), log.clone()).unwrap();
    for &cut in cuts {
        while session.cursor() < cut && session.step() {}
        let resumed = round_trip(session.engine());
        assert_eq!(resumed.queries_done() as u64, session.cursor());
        session = ReplaySession::new(resumed, log.clone()).unwrap();
    }
    let report = session.run_to_end();
    let digest = engine_digest(session.engine());
    (report, digest)
}

#[test]
fn soak_chunked_run_is_bit_identical_to_straight_run() {
    // Edge preset: 600 queries × 8 samples of gsm8k (longest decode
    // budgets) under crash + error-rate faults, bandwidth derate, idle
    // creep, and ±5% contention noise.
    let edge_qs = queries(Dataset::Gsm8k, 17, 600);
    let edge_log = EventLog::from_queries(&edge_qs, 8);
    let edge_opts = soak_options(17, "gpu0", "npu0");
    let (edge_straight, edge_digest) =
        run_straight(FleetPreset::EdgeBox, edge_opts.clone(), &edge_log);
    let (edge_chunked, edge_chunked_digest) =
        run_chunked(FleetPreset::EdgeBox, edge_opts, &edge_log, &[150, 275, 430]);
    assert_eq!(edge_chunked, edge_straight);
    assert_eq!(edge_chunked_digest, edge_digest);

    // Datacenter preset: the Cloud fleet's single device gets the
    // drift/noise treatment but no hard crash (losing the only device
    // would just measure the loss path, not replay fidelity).
    let cloud_qs = queries(Dataset::Gsm8k, 23, 250);
    let cloud_log = EventLog::from_queries(&cloud_qs, 8);
    let cloud_opts = SimOptions {
        seed: 23,
        drift_plan: DriftPlan::new(vec![
            DriftScenario::bandwidth_derate("cloud-gpu0".into(), 8.0, 0.7),
            DriftScenario::contention_noise("cloud-gpu0".into(), 1.0, 0.05),
        ]),
        ..SimOptions::default()
    };
    let (cloud_straight, cloud_digest) =
        run_straight(FleetPreset::Cloud, cloud_opts.clone(), &cloud_log);
    let (cloud_chunked, cloud_chunked_digest) =
        run_chunked(FleetPreset::Cloud, cloud_opts, &cloud_log, &[60, 190]);
    assert_eq!(cloud_chunked, cloud_straight);
    assert_eq!(cloud_chunked_digest, cloud_digest);

    // The soak must actually exercise a long logical clock: ≥100k
    // generated tokens across the two presets.
    let tokens = edge_straight.tokens_generated + cloud_straight.tokens_generated;
    assert!(tokens >= 100_000, "soak too short: {tokens} tokens");
}

// ---------------------------------------------------------------------
// Crash-recovery drill matrix
// ---------------------------------------------------------------------

#[test]
fn drill_matrix_passes_on_every_preset() {
    let qs = queries(Dataset::WikiText103, 0, 40);
    let options = SimOptions::default();
    // Pinned kills at the first tick, mid-run, and the last tick, plus
    // two per-seed fuzzed kill points; checkpoints every 10 ticks.
    let outcomes = drill_all_presets(&options, &qs, 4, 10, &[1, 20, 39], 2).unwrap();
    assert_eq!(outcomes.len(), FleetPreset::all().len() * 5);
    for o in &outcomes {
        assert!(
            o.passed(),
            "drill failed: preset {:?} kill@{} restore@{} (digest match {}, report match {})",
            o.preset,
            o.kill_tick,
            o.checkpoint_tick,
            o.digest_match,
            o.report_match
        );
        assert!(o.checkpoint_tick <= o.kill_tick);
    }
}

// ---------------------------------------------------------------------
// Cross-replica desync detection
// ---------------------------------------------------------------------

#[test]
fn stale_coefficient_replica_desyncs_at_an_exact_tick() {
    let qs = queries(Dataset::WikiText103, 5, 60);
    let log = EventLog::from_queries(&qs, 4);
    let options = SimOptions { seed: 5, ..SimOptions::default() };

    let primary = engine(FleetPreset::EdgeBox, options.clone());
    let stale = stale_replica(
        &primary,
        DevIdx(1),
        CalibratedSpec { bandwidth_scale: 0.5, ..CalibratedSpec::identity() },
    );
    let report = detect_desync(primary, stale, &log, 1).unwrap();
    let tick = report.first_divergence_tick.expect("stale replica must diverge");
    assert!(tick >= 1, "divergence tick must be a stepped tick, got {tick}");
    assert!(
        report.components.contains(&"calibration"),
        "expected the calibration component to be named, got {:?}",
        report.components
    );
    assert!(!report.in_sync());

    // Identical replicas stay in sync through the whole log.
    let a = engine(FleetPreset::EdgeBox, options.clone());
    let b = engine(FleetPreset::EdgeBox, options);
    let clean = detect_desync(a, b, &log, 5).unwrap();
    assert!(clean.in_sync(), "identical replicas diverged: {clean:?}");
    assert_eq!(clean.first_divergence_tick, None);
    assert!(clean.components.is_empty());
}

// ---------------------------------------------------------------------
// Forward migration
// ---------------------------------------------------------------------

#[test]
fn v1_snapshot_migrates_forward_to_the_same_digest() {
    let qs = queries(Dataset::WikiText103, 7, 20);
    let mut e = engine(FleetPreset::EdgeBox, SimOptions { seed: 7, ..SimOptions::default() });
    let oracle = CoverageOracle::new(e.seed());
    for q in &qs {
        e.step_query(q, 4, &oracle);
    }

    // Forge the v1 form of this snapshot: no `clock.pjrt_time_scale`
    // (the field v2 introduced; its engine default is 1.0, which is
    // exactly what the migration hook must re-insert) and no `des`
    // component (v3) — the whole v1 → v2 → v3 chain runs on restore.
    let mut doc = snapshot_engine(&e);
    let Json::Obj(top) = &mut doc else { panic!("snapshot must be an object") };
    top.insert("format_version".to_string(), Json::Num(1.0));
    let Some(Json::Obj(engine_obj)) = top.get_mut("engine") else {
        panic!("snapshot must carry an engine component object")
    };
    assert!(engine_obj.remove("des").is_some());
    let Some(Json::Obj(clock)) = engine_obj.get_mut("clock") else {
        panic!("engine state must carry a clock component")
    };
    assert!(clock.remove("pjrt_time_scale").is_some());

    let restored = restore_engine(&doc).unwrap();
    assert_eq!(engine_digest(&restored), engine_digest(&e));
    assert_eq!(
        snapshot_engine(&restored).to_string(),
        snapshot_engine(&e).to_string()
    );
}

#[test]
fn v2_snapshot_migrates_to_the_same_digest_with_a_consumed_failure_plan() {
    // A hard fail + recover both land well before the snapshot point,
    // so the derived `des` defaults must reconstruct a non-zero
    // failure-schedule cursor (2 consumed transitions) — not just the
    // trivial empty-plan case.
    let options = SimOptions {
        seed: 11,
        failure_plan: FailurePlan::new(vec![FailureScenario {
            device: "npu0".into(),
            kind: FailureKind::Crash,
            at_s: 0.001,
            recover_after_s: Some(0.002),
        }]),
        ..SimOptions::default()
    };
    let qs = queries(Dataset::WikiText103, 11, 40);
    let mut e = engine(FleetPreset::EdgeBox, options);
    let oracle = CoverageOracle::new(e.seed());
    for q in &qs {
        e.step_query(q, 4, &oracle);
    }

    // Forge the v2 form: drop `des`, tag format_version 2.
    let mut doc = snapshot_engine(&e);
    let Json::Obj(top) = &mut doc else { panic!("snapshot must be an object") };
    top.insert("format_version".to_string(), Json::Num(2.0));
    let Some(Json::Obj(engine_obj)) = top.get_mut("engine") else {
        panic!("snapshot must carry an engine component object")
    };
    assert!(engine_obj.remove("des").is_some());

    let restored = restore_engine(&doc).unwrap();
    assert_eq!(engine_digest(&restored), engine_digest(&e));
    assert_eq!(
        snapshot_engine(&restored).to_string(),
        snapshot_engine(&e).to_string()
    );
}

// ---------------------------------------------------------------------
// Fleet-scale drill: the metro preset
// ---------------------------------------------------------------------

#[test]
fn metro_fleet_drill_recovers_bit_exactly() {
    // 100 devices through the kill/restore/replay drill. Short log,
    // tight checkpoint cadence: the point is the state surface (one
    // window component per device, 100-entry pending intervals), not
    // the soak length. Run it under the canonical dispatch order and
    // under a fuzzed same-tick schedule — a drill is deterministic
    // either way, because the fuzzed order is a pure function of
    // (seed, tick) and survives checkpoint/restore.
    let qs = queries(Dataset::WikiText103, 31, 12);
    for schedule in [ScheduleMode::Canonical, ScheduleMode::Fuzzed(0xBEEF)] {
        let options = SimOptions { seed: 31, schedule, ..SimOptions::default() };
        let outcomes =
            drill_preset(FleetPreset::Metro, options, &qs, 2, 4, &[3, 11], 1).unwrap();
        assert_eq!(outcomes.len(), 3);
        for o in &outcomes {
            assert!(
                o.passed(),
                "metro drill failed under {schedule:?}: kill@{} restore@{} \
                 (digest match {}, report match {})",
                o.kill_tick,
                o.checkpoint_tick,
                o.digest_match,
                o.report_match
            );
        }
    }
}
