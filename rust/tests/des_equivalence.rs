//! Schedule-mode equivalence: the DES core's central property.
//!
//! The component scheduler must be an identity refactor of the legacy
//! synchronous tick loop, and same-tick within-stage dispatch order
//! must be immaterial:
//!
//!   * Legacy (direct sequential calls), Canonical (heap dispatch in
//!     `(tick, ComponentId)` order), and Fuzzed (per-`(seed, tick)`
//!     Fisher–Yates over within-stage runs) produce bit-identical
//!     `SimReport`s — state digest included — on EVERY fleet preset,
//!     under active failure, drift, and contention-noise plans;
//!   * the metro fleet-scale preset (100 window components per tick:
//!     the largest same-tick permutation surface in the tree) agrees
//!     across modes too;
//!   * non-default clock dividers are real state: they serialize with
//!     the snapshot, the executor/fold pins are enforced, and a
//!     mid-run restore continues bit-identically.

use qeil::calibration::drift::{DriftPlan, DriftScenario};
use qeil::coordinator::allocation::ModelShape;
use qeil::devices::failure::{FailureKind, FailurePlan, FailureScenario};
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::experiments::runner::default_meta;
use qeil::json::Json;
use qeil::sim::des::{ComponentId, Stage};
use qeil::sim::engine::{SimEngine, SimOptions, SimReport};
use qeil::sim::ScheduleMode;
use qeil::snapshot::{engine_digest, restore_engine, snapshot_engine};
use qeil::workload::coverage::CoverageOracle;
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::{Query, WorkloadGenerator};

fn shape() -> ModelShape {
    ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2))
}

fn queries(n: usize) -> Vec<Query> {
    WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, 42).queries(n)
}

/// Failure + drift + contention noise aimed at the preset's own
/// devices: crash-then-recover the last device, drift and jitter the
/// first — the regime where every stage (environment, model, planning,
/// execution, windows, fold) has real same-tick work to reorder.
fn stress_options(preset: FleetPreset, schedule: ScheduleMode) -> SimOptions {
    let fleet = Fleet::preset(preset);
    let first = fleet.devices()[0].id.clone();
    let last = fleet.devices()[fleet.len() - 1].id.clone();
    SimOptions {
        seed: 7,
        schedule,
        failure_plan: FailurePlan::new(vec![FailureScenario {
            device: last,
            kind: FailureKind::Crash,
            at_s: 0.15,
            recover_after_s: Some(0.2),
        }]),
        drift_plan: DriftPlan::new(vec![
            DriftScenario::bandwidth_derate(first.clone(), 0.1, 0.5),
            DriftScenario::contention_noise(first, 0.2, 0.05),
        ]),
        ..SimOptions::default()
    }
}

fn run(preset: FleetPreset, schedule: ScheduleMode, n: usize, samples: u32) -> SimReport {
    let mut e =
        SimEngine::new(Fleet::preset(preset), shape(), stress_options(preset, schedule));
    e.run(&queries(n), samples).unwrap()
}

#[test]
fn schedule_modes_agree_on_every_preset() {
    for preset in FleetPreset::all() {
        let legacy = run(preset, ScheduleMode::Legacy, 100, 8);
        let canonical = run(preset, ScheduleMode::Canonical, 100, 8);
        assert_eq!(
            canonical, legacy,
            "{preset:?}: heap dispatch diverged from the synchronous loop"
        );
        for fuzz_seed in [0xA5u64, 0x5EED] {
            let fuzzed = run(preset, ScheduleMode::Fuzzed(fuzz_seed), 100, 8);
            assert_eq!(
                fuzzed, canonical,
                "{preset:?}: fuzz seed {fuzz_seed:#x} surfaced order-sensitive state"
            );
        }
    }
}

#[test]
fn schedule_modes_agree_at_metro_scale() {
    // 100 same-tick window components: any cross-device accumulation
    // that survives the 4-device presets by luck gets 100! orderings
    // here. Short run — the surface is the point, not the soak.
    let legacy = run(FleetPreset::Metro, ScheduleMode::Legacy, 10, 2);
    let canonical = run(FleetPreset::Metro, ScheduleMode::Canonical, 10, 2);
    assert_eq!(canonical, legacy, "metro: heap dispatch diverged from the loop");
    let fuzzed = run(FleetPreset::Metro, ScheduleMode::Fuzzed(0xF1EE7), 10, 2);
    assert_eq!(fuzzed, canonical, "metro: fuzzed window order diverged");
}

#[test]
fn clock_dividers_serialize_and_survive_restore() {
    let qs = queries(40);
    let options = SimOptions { seed: 3, ..SimOptions::default() };
    let mut warm = SimEngine::new(Fleet::preset(FleetPreset::EdgeBox), shape(), options);

    // The executor and the ledger fold are pinned to every tick: the
    // executor defines the tick, and deferring the fold across ticks
    // would reorder the energy scalar accumulation it exists to fix.
    assert!(!warm.set_component_divider(ComponentId::of(Stage::Execution), 2));
    assert!(!warm.set_component_divider(ComponentId::of(Stage::Fold), 2));
    // Calibration refresh every 3rd tick, replan gate every 2nd, one
    // device's window integration every 2nd.
    assert!(warm.set_component_divider(ComponentId::of(Stage::Model), 3));
    assert!(warm.set_component_divider(ComponentId::of(Stage::Planning), 2));
    assert!(warm.set_component_divider(ComponentId::window(1), 2));

    // Cut the snapshot after an odd tick so window(1) holds a staged
    // (non-zero) wall interval — `pending_dt` must carry it across the
    // process boundary.
    let oracle = CoverageOracle::new(warm.seed());
    for q in &qs[..22] {
        warm.step_query(q, 4, &oracle);
    }
    let text = snapshot_engine(&warm).to_string();
    let mut restored = restore_engine(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(
        snapshot_engine(&restored).to_string(),
        text,
        "divider + staged-interval state must round-trip byte-exactly"
    );
    assert_eq!(engine_digest(&restored), engine_digest(&warm));

    for q in &qs[22..] {
        let a = warm.step_query(q, 4, &oracle);
        let b = restored.step_query(q, 4, &oracle);
        assert_eq!(a, b, "restored divider run must step bit-identically");
    }
    assert_eq!(restored.finish(), warm.finish());
}
