//! Integration tests over the real PJRT runtime: load the gpt2 artifact,
//! execute prefill + decode, and validate the generation session.
//!
//! These tests need `make artifacts` to have run; they are skipped (with
//! a message) when artifacts are absent so `cargo test` stays green on a
//! fresh checkout.

use qeil::rng::Pcg;
use qeil::runtime::session::Sampling;
use qeil::runtime::{Engine, GenerationSession};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn engine_with(variant: &str) -> Option<Engine> {
    let dir = artifacts_dir()?;
    let mut engine = Engine::new(dir).expect("engine");
    engine.load_variant(variant).expect("load variant");
    Some(engine)
}

#[test]
fn prefill_produces_finite_logits_and_caches() {
    let Some(engine) = engine_with("gpt2") else { return };
    let meta = engine.meta("gpt2").unwrap().clone();
    let prompt: Vec<i32> = (0..meta.prefill_len as i32).collect();
    let out = engine.prefill("gpt2", &prompt).unwrap();
    assert_eq!(out.logits.len(), meta.prefill_len * meta.vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn decode_step_changes_logits_with_position() {
    let Some(engine) = engine_with("gpt2") else { return };
    let meta = engine.meta("gpt2").unwrap().clone();
    let prompt: Vec<i32> = (0..meta.prefill_len as i32).collect();
    let out = engine.prefill("gpt2", &prompt).unwrap();
    let d1 = engine
        .decode("gpt2", 5, &out.k_cache, &out.v_cache, meta.prefill_len as i32)
        .unwrap();
    let d2 = engine
        .decode("gpt2", 6, &d1.k_cache, &d1.v_cache, meta.prefill_len as i32 + 1)
        .unwrap();
    assert_eq!(d1.logits.len(), meta.vocab);
    assert!(d1.logits.iter().zip(&d2.logits).any(|(a, b)| a != b));
}

#[test]
fn greedy_generation_is_deterministic() {
    let Some(engine) = engine_with("gpt2") else { return };
    let meta = engine.meta("gpt2").unwrap().clone();
    let prompt: Vec<i32> = (0..meta.prefill_len as i32).map(|i| i % 7).collect();
    let mut outputs = Vec::new();
    for _ in 0..2 {
        let (mut session, logits) = GenerationSession::start(&engine, "gpt2", &prompt).unwrap();
        let mut rng = Pcg::seeded(0);
        let tokens = session.generate(logits, 6, Sampling::Greedy, &mut rng).unwrap();
        outputs.push(tokens);
    }
    assert_eq!(outputs[0], outputs[1]);
}

#[test]
fn generation_respects_cache_capacity() {
    let Some(engine) = engine_with("gpt2") else { return };
    let meta = engine.meta("gpt2").unwrap().clone();
    let prompt: Vec<i32> = (0..meta.prefill_len as i32).collect();
    let (mut session, logits) = GenerationSession::start(&engine, "gpt2", &prompt).unwrap();
    let capacity = (meta.max_seq - meta.prefill_len) as usize;
    let mut rng = Pcg::seeded(1);
    // Ask for far more than fits: must stop at capacity, not error.
    let tokens = session.generate(logits, capacity + 50, Sampling::Greedy, &mut rng).unwrap();
    assert_eq!(tokens.len(), capacity);
    assert_eq!(session.remaining(), 0);
    // One more step must fail loudly.
    assert!(session.step(0).is_err());
}

#[test]
fn invalid_inputs_rejected() {
    let Some(engine) = engine_with("gpt2") else { return };
    let meta = engine.meta("gpt2").unwrap().clone();
    // Wrong prompt length.
    assert!(engine.prefill("gpt2", &[1, 2, 3]).is_err());
    // Out-of-vocab token.
    let mut prompt: Vec<i32> = (0..meta.prefill_len as i32).collect();
    prompt[0] = meta.vocab as i32;
    assert!(engine.prefill("gpt2", &prompt).is_err());
    // Unknown variant.
    assert!(engine.prefill("nonexistent", &[0; 32]).is_err());
}

#[test]
fn temperature_sampling_varies_with_seed() {
    let Some(engine) = engine_with("gpt2") else { return };
    let meta = engine.meta("gpt2").unwrap().clone();
    let prompt: Vec<i32> = (0..meta.prefill_len as i32).collect();
    let mut outs = Vec::new();
    for seed in [1u64, 2] {
        let (mut session, logits) = GenerationSession::start(&engine, "gpt2", &prompt).unwrap();
        let mut rng = Pcg::seeded(seed);
        outs.push(session.generate(logits, 8, Sampling::Temperature(1.5), &mut rng).unwrap());
    }
    assert_ne!(outs[0], outs[1], "different seeds should explore differently");
}
