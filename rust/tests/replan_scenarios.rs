//! Fault/thermal scenario matrix for event-driven re-planning with the
//! warm-start plan cache (paper §6 reliability claims: zero thermal
//! throttling, 100% fault recovery).
//!
//! Every fleet preset runs failure→recovery, cascading two-device
//! failure (multi-device presets), and thermal-shed scenarios, locking
//! down the invalidation contract:
//!
//! * a safety transition (failure / recovery / graduation / shedding-
//!   band crossing) bumps the monotone safety-state version and forces
//!   exactly one replanning episode — coincident transitions batch;
//! * recovery returns the fleet to an already-planned health signature,
//!   so the cache restores the pre-failure allocation **bit-exactly**;
//! * `failures` / `replans` / `plan_cache_hits` counters reconcile with
//!   the replan trail.

use qeil::coordinator::allocation::ModelShape;
use qeil::coordinator::orchestrator::Orchestrator;
use qeil::coordinator::pgsam::PgsamConfig;
use qeil::devices::failure::{FailureKind, FailurePlan, FailureScenario};
use qeil::devices::fleet::{Fleet, FleetPreset};
use qeil::experiments::runner::default_meta;
use qeil::safety::thermal_guard::ThermalGuard;
use qeil::sim::engine::{SimEngine, SimOptions, SimReport};
use qeil::sim::ScheduleMode;
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::{Query, WorkloadGenerator};

fn engine(preset: FleetPreset, options: SimOptions) -> SimEngine {
    let shape = ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2));
    SimEngine::new(Fleet::preset(preset), shape, options)
}

fn queries(n: usize) -> Vec<Query> {
    WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, 42).queries(n)
}

/// Counters and trail must always reconcile, and versions must be
/// strictly increasing: one episode per safety transition batch, never
/// a redundant replan.
fn assert_trail_consistent(preset: FleetPreset, r: &SimReport) {
    assert_eq!(
        r.replans as usize,
        r.replan_trail.len(),
        "{preset:?}: replans counter vs trail length"
    );
    let hits = r.replan_trail.iter().filter(|e| e.cache_hit).count() as u64;
    assert_eq!(r.plan_cache_hits, hits, "{preset:?}: cache-hit counter vs trail");
    for pair in r.replan_trail.windows(2) {
        assert!(
            pair[0].version < pair[1].version,
            "{preset:?}: replan without a version bump ({} -> {})",
            pair[0].version,
            pair[1].version
        );
    }
}

#[test]
fn failure_recovery_replans_and_restores_bit_exactly_on_every_preset() {
    for preset in FleetPreset::all() {
        let fleet = Fleet::preset(preset);
        // Prefer a victim the healthy PGSAM winner does NOT use (same
        // seed 0 the engine plans with): its failure leaves the
        // archived winner feasible at-or-below the degraded greedy
        // seed, so the degraded replan is guaranteed to ENGAGE the
        // warm archive. Falls back to the last device when the winner
        // uses the whole fleet (single-device presets).
        let shape = ModelShape::from_family(ModelFamily::Gpt2, &default_meta(ModelFamily::Gpt2));
        let orch = Orchestrator::new(&fleet);
        let healthy = orch.pgsam_outcome(&shape, &PgsamConfig::default().with_seed(0)).unwrap();
        let unused_victim = fleet
            .devices()
            .iter()
            .rev()
            .find(|d| healthy.plan.iter().all(|&i| fleet.id_at(i) != &d.id))
            .map(|d| d.id.clone());
        let victim =
            unused_victim.clone().unwrap_or_else(|| fleet.devices()[fleet.len() - 1].id.clone());
        let plan = FailurePlan::new(vec![FailureScenario {
            device: victim.clone(),
            kind: FailureKind::Crash,
            at_s: 0.15,
            recover_after_s: Some(0.2),
        }]);
        let mut e = engine(preset, SimOptions { failure_plan: plan, ..Default::default() });
        let r = e.run(&queries(200), 8).unwrap();
        assert_trail_consistent(preset, &r);
        assert!(r.failures >= 1, "{preset:?}: failure must fire");
        assert!(r.recoveries >= 1, "{preset:?}: recovery must fire");
        assert!(
            r.replans >= 3,
            "{preset:?}: initial + failure + recovery episodes, got {}",
            r.replans
        );

        // Invalidation fires on each transition, but only two health
        // signatures are ever planned cold: healthy and degraded (on a
        // single-device fleet the degraded signature plans to a
        // surfaced error — still exactly one cold episode).
        let misses: Vec<_> = r.replan_trail.iter().filter(|e| !e.cache_hit).collect();
        assert_eq!(misses.len(), 2, "{preset:?}: cold episodes != distinct signatures");
        let first = &r.replan_trail[0];
        assert!(!first.cache_hit && first.plan_error.is_none());
        if fleet.len() >= 2 {
            if unused_victim.is_some() {
                assert!(
                    misses[1].warm_restart,
                    "{preset:?}: healthy winner avoids the victim — the degraded replan \
                     must engage the warm archive"
                );
            }
            assert!(misses[1].plan.iter().all(|&d| fleet.id_at(d) != &victim));
        } else {
            assert_eq!(misses[1].planner, "none", "{preset:?}: no device left to plan on");
            assert!(misses[1].plan_error.is_some());
        }

        // Recovery restores the pre-failure allocation bit-exactly via
        // a pure cache hit. (The recovery episode is the LAST trail
        // event: shed-band crossings during the outage may legally hit
        // the degraded key, but after recovery every lookup is the
        // healthy signature again.)
        let hit = r.replan_trail.last().unwrap();
        assert!(
            hit.cache_hit,
            "{preset:?}: the post-recovery replan must be a pure cache hit"
        );
        assert_eq!(hit.plan, first.plan, "{preset:?}: recovery must restore the plan");
        assert_eq!(hit.plan_energy_j.to_bits(), first.plan_energy_j.to_bits());
        assert_eq!(hit.planner, first.planner);

        // The report's planner trail reflects the final (recovered)
        // state: same plan energy as the initial healthy plan.
        assert_eq!(r.plan_energy_j.to_bits(), first.plan_energy_j.to_bits());
        // With safety on, a single transient failure loses no queries
        // on multi-device fleets.
        if fleet.len() >= 2 {
            assert_eq!(r.queries_lost, 0, "{preset:?}: redundancy must absorb the failure");
        }
    }
}

#[test]
fn coincident_cascading_failures_batch_into_one_replan() {
    for preset in [FleetPreset::EdgeBox, FleetPreset::MultiVendor] {
        let fleet = Fleet::preset(preset);
        let (a, b) = (fleet.devices()[0].id.clone(), fleet.devices()[1].id.clone());
        let scenario = |device: &qeil::devices::spec::DeviceId, at_s: f64| FailureScenario {
            device: device.clone(),
            kind: FailureKind::Crash,
            at_s,
            recover_after_s: None,
        };

        // Both devices crash on the same tick: the two health
        // transitions coalesce into ONE version jump and ONE anneal.
        let plan = FailurePlan::new(vec![scenario(&a, 0.15), scenario(&b, 0.15)]);
        let mut e = engine(preset, SimOptions { failure_plan: plan, ..Default::default() });
        let r = e.run(&queries(150), 8).unwrap();
        assert_trail_consistent(preset, &r);
        assert_eq!(r.failures, 2, "{preset:?}: both failures counted");
        let misses = r.replan_trail.iter().filter(|e| !e.cache_hit).count();
        assert_eq!(
            misses, 2,
            "{preset:?}: healthy + both-failed — coincident events must batch, got {misses}"
        );
        let last_cold = r.replan_trail.iter().filter(|e| !e.cache_hit).last().unwrap();
        assert!(last_cold
            .plan
            .iter()
            .all(|&d| fleet.id_at(d) != &a && fleet.id_at(d) != &b));

        // Staggered: the same two failures on distinct ticks cost one
        // replan each (three signatures planned cold in total).
        let plan = FailurePlan::new(vec![scenario(&a, 0.15), scenario(&b, 0.45)]);
        let mut e = engine(preset, SimOptions { failure_plan: plan, ..Default::default() });
        let r = e.run(&queries(150), 8).unwrap();
        assert_trail_consistent(preset, &r);
        assert_eq!(r.failures, 2);
        let misses = r.replan_trail.iter().filter(|e| !e.cache_hit).count();
        assert_eq!(misses, 3, "{preset:?}: healthy + first-failed + both-failed signatures");
        assert_eq!(r.queries_lost, 0, "{preset:?}: the surviving devices absorb the cascade");
    }
}

#[test]
fn fuzzed_schedules_replay_the_replan_trail_bit_exactly() {
    // Pinned fuzz regression for the replan path: a fuzzed same-tick
    // dispatch order must reproduce the ENTIRE canonical trail —
    // replan episodes, cache hits, plan energies, and the report —
    // bit-exactly while a failure→recovery scenario and an aggressive
    // thermal guard are both live. This is the surface the original
    // ledger-fold ordering bug hid in: same-tick window integrations
    // folding energy in permuted order ahead of a replan gate.
    for preset in [FleetPreset::EdgeBox, FleetPreset::MultiVendor] {
        let fleet = Fleet::preset(preset);
        let victim = fleet.devices()[fleet.len() - 1].id.clone();
        let options = |schedule: ScheduleMode| SimOptions {
            schedule,
            guard: ThermalGuard { theta: 0.1, ..ThermalGuard::default() },
            failure_plan: FailurePlan::new(vec![FailureScenario {
                device: victim.clone(),
                kind: FailureKind::Crash,
                at_s: 0.15,
                recover_after_s: Some(0.2),
            }]),
            ..Default::default()
        };
        let mut canonical_engine = engine(preset, options(ScheduleMode::Canonical));
        let canonical = canonical_engine.run(&queries(150), 8).unwrap();
        assert_trail_consistent(preset, &canonical);
        assert!(canonical.failures >= 1, "{preset:?}: scenario must exercise a failure");

        for fuzz_seed in [0x0DDBA11u64, 0xCAFE] {
            let mut fuzzed_engine = engine(preset, options(ScheduleMode::Fuzzed(fuzz_seed)));
            let fuzzed = fuzzed_engine.run(&queries(150), 8).unwrap();
            assert_eq!(
                fuzzed, canonical,
                "{preset:?}: fuzz seed {fuzz_seed:#x} perturbed the replan trajectory"
            );
        }
    }
}

#[test]
fn thermal_shedding_band_change_replans_via_cache_hit_on_every_preset() {
    for preset in FleetPreset::all() {
        // An aggressive guard point below ambient forces immediate
        // shedding: the first thermal window crosses every device into
        // a shedding band — a safety transition with an UNCHANGED
        // schedulability mask, so the replan must be a pure cache hit
        // returning the identical plan.
        let guard = ThermalGuard { theta: 0.1, ..ThermalGuard::default() };
        let mut e = engine(preset, SimOptions { guard, ..Default::default() });
        let r = e.run(&queries(60), 8).unwrap();
        assert_trail_consistent(preset, &r);
        assert_eq!(r.failures, 0, "{preset:?}: thermal shedding is not a failure");
        assert!(
            r.replans >= 2,
            "{preset:?}: a shedding-band crossing must trigger a replan, got {}",
            r.replans
        );
        assert!(r.plan_cache_hits >= 1, "{preset:?}: unchanged signature must hit");
        let first = &r.replan_trail[0];
        assert!(!first.cache_hit);
        for event in &r.replan_trail[1..] {
            assert!(event.cache_hit, "{preset:?}: mask unchanged — every later episode hits");
            assert_eq!(event.plan, first.plan, "{preset:?}: hit must return the same plan");
        }
    }
}
