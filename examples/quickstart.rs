//! Quickstart: load an AOT-compiled model variant through PJRT, run a
//! prefill + a few decode steps, and print the tokens.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use qeil::rng::Pcg;
use qeil::runtime::session::Sampling;
use qeil::runtime::{Engine, GenerationSession};

fn main() -> Result<()> {
    // 1. Load + compile the artifact (HLO text -> PJRT executable).
    let mut engine = Engine::new("artifacts")?;
    engine.load_variant("gpt2")?;
    let meta = engine.meta("gpt2")?.clone();
    println!(
        "loaded gpt2: {} layers, d_model {}, vocab {} (scaled stand-in for the paper's {}-param family)",
        meta.n_layers, meta.d_model, meta.vocab, meta.paper_params
    );

    // 2. Prefill a prompt.
    let prompt: Vec<i32> = (0..meta.prefill_len as i32).map(|i| (i * 7) % meta.vocab as i32).collect();
    let (mut session, logits) = GenerationSession::start(&engine, "gpt2", &prompt)?;
    println!("prefill: {} positions in {:.2} ms", meta.prefill_len, session.prefill_seconds * 1e3);

    // 3. Decode greedily.
    let mut rng = Pcg::seeded(0);
    let tokens = session.generate(logits, 16, Sampling::Greedy, &mut rng)?;
    println!("greedy tokens: {tokens:?}");
    println!(
        "decode compute: {:.2} ms total ({:.3} ms/token)",
        (session.compute_seconds - session.prefill_seconds) * 1e3,
        (session.compute_seconds - session.prefill_seconds) * 1e3 / tokens.len() as f64
    );
    Ok(())
}
