//! Scaling sweep: measure coverage curves across sample budgets for all
//! five model families, fit the scaling law C(S) = 1 − exp(−αS^β), and
//! print β with bootstrap CIs — the interactive companion to Tables 1–2
//! and Figure 6.
//!
//!     cargo run --release --example scaling_sweep

use anyhow::Result;

use qeil::experiments::scaling::coverage_curve;
use qeil::scaling::bootstrap::bootstrap_ci;
use qeil::scaling::fit::{fit_coverage_law, LmOptions};
use qeil::workload::datasets::ModelFamily;

fn main() -> Result<()> {
    let budgets = [1u32, 2, 5, 10, 15, 20, 30, 50];
    println!("coverage scaling sweep (WikiText-103, 600 queries/family)\n");
    println!("{:<16} {}", "model", "C(S) at S = 1, 2, 5, 10, 15, 20, 30, 50");
    let mut betas = Vec::new();
    for family in ModelFamily::all() {
        let curve = coverage_curve(family, &budgets, 600, 42);
        let cells: Vec<String> = curve.iter().map(|(_, c)| format!("{:.2}", c)).collect();
        println!("{:<16} {}", family.variant(), cells.join("  "));
        let fit = fit_coverage_law(&curve, &LmOptions::default())?;
        let ci = bootstrap_ci(&curve, 1000, 0.95, 42)?;
        println!(
            "{:<16} β = {:.3}  (95% CI [{:.3}, {:.3}])  α = {:.4}  R² = {:.4}\n",
            "", fit.beta, ci.lo, ci.hi, fit.alpha, fit.r_squared
        );
        betas.push(fit.beta);
    }
    let mean = betas.iter().sum::<f64>() / betas.len() as f64;
    println!("mean β across families: {mean:.3}  (paper: 0.70 ± 0.04, architecture-invariant)");
    Ok(())
}
