//! End-to-end serving driver (DESIGN.md's E2E validation): load a real
//! small model, serve batched requests through the full stack —
//! validation → rate limiting → PJRT execution → output sanity — and
//! report latency/throughput, then run the heterogeneous orchestration
//! simulation on the same workload and report the paper's headline
//! metrics side by side.
//!
//!     make artifacts && cargo run --release --example serve_heterogeneous

use anyhow::Result;

use qeil::config::ExperimentConfig;
use qeil::experiments::runner::{run_config, run_pair};
use qeil::gateway::SlaClass;
use qeil::rng::Pcg;
use qeil::server::api::InferenceRequest;
use qeil::server::service::{Service, ServiceConfig};
use qeil::workload::datasets::{Dataset, ModelFamily};
use qeil::workload::generator::WorkloadGenerator;
use qeil::workload::trace::RequestTrace;

fn main() -> Result<()> {
    // ---------- Part 1: REAL serving through PJRT ----------
    println!("═══ Part 1: real PJRT serving (gpt2 variant, batched Poisson trace) ═══");
    let config = ServiceConfig::default();
    let mut service = Service::start(&config)?;

    let queries = WorkloadGenerator::new(Dataset::WikiText103, ModelFamily::Gpt2, 7).queries(48);
    let trace = RequestTrace::poisson(queries, 16.0, 6, 7);
    let mut rng = Pcg::seeded(7);

    for traced in trace.requests() {
        let prompt: Vec<i64> =
            (0..config.max_prompt_tokens).map(|_| rng.below(config.vocab as u64) as i64).collect();
        let request = InferenceRequest {
            client_id: traced.client_id,
            class: SlaClass::Interactive,
            prompt,
            max_new_tokens: 12,
            temperature: 0.8,
            seed: rng.next_u64(),
        };
        let _ = service.handle(request, traced.arrival_s);
    }
    let stats = service.stats();
    println!(
        "served {} requests | {} tokens | mean latency {:.2} ms | max {:.2} ms | throughput {:.0} tok/s | compute share {:.0}%",
        stats.served,
        stats.tokens_out,
        stats.mean_latency_s() * 1e3,
        stats.max_latency_s * 1e3,
        stats.throughput_tps(),
        100.0 * stats.total_compute_s / stats.total_latency_s.max(1e-9),
    );

    // ---------- Part 2: heterogeneous orchestration ----------
    println!("\n═══ Part 2: QEIL heterogeneous orchestration vs Standard (simulated edge box) ═══");
    println!(
        "{:<10} {:>12} {:>14} {:>12} {:>14} {:>12}",
        "model", "pass@k", "energy (kJ)", "power (W)", "latency (ms)", "IPW"
    );
    for family in ModelFamily::all() {
        let (s, e) = run_pair(family, Dataset::WikiText103, 7)?;
        println!(
            "{:<10} {:>5.1}→{:<6.1} {:>6.1}→{:<7.1} {:>5.0}→{:<6.0} {:>6.2}→{:<7.2} {:>5.2}→{:<6.2}",
            family.variant(),
            s.pass_at_k_pct,
            e.pass_at_k_pct,
            s.energy_kj,
            e.energy_kj,
            s.power_w,
            e.power_w,
            s.latency_ms,
            e.latency_ms,
            s.ipw,
            e.ipw,
        );
    }

    // Device utilization snapshot (paper Fig. 4).
    let m = run_config(&ExperimentConfig::energy_aware(ModelFamily::Gpt2, Dataset::WikiText103))?;
    println!("\ndevice utilization (QEIL, gpt2): {:?}", m.utilization);
    println!("peak temps: {:?}", m.peak_temp_c);
    println!("thermal throttle events: {} | queries lost: {}", m.throttle_events, m.queries_lost);
    Ok(())
}
