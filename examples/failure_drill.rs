//! Failure drill: inject device crashes and thermal stress into the
//! simulated edge box and watch the safety monitor recover — the
//! interactive companion to Tables 10–12.
//!
//!     cargo run --release --example failure_drill

use anyhow::Result;

use qeil::config::ExperimentConfig;
use qeil::devices::failure::{FailureKind, FailurePlan, FailureScenario};
use qeil::devices::spec::DeviceSpec;
use qeil::devices::thermal::ThermalState;
use qeil::experiments::runner::run_config_with;
use qeil::safety::thermal_guard::ThermalGuard;
use qeil::workload::datasets::{Dataset, ModelFamily};

fn main() -> Result<()> {
    println!("═══ Drill 1: cascading device failures ═══");
    let scenarios: Vec<(&str, Vec<(&str, FailureKind, f64)>)> = vec![
        ("decode lead (NPU) dies mid-run", vec![("npu0", FailureKind::Crash, 0.5)]),
        ("prefill lead (dGPU) hangs", vec![("gpu0", FailureKind::Hang, 0.5)]),
        (
            "rolling catastrophe: NPU, then both GPUs",
            vec![
                ("npu0", FailureKind::Crash, 0.3),
                ("gpu0", FailureKind::Crash, 0.8),
                ("igpu0", FailureKind::Crash, 1.2),
            ],
        ),
    ];
    let cfg = ExperimentConfig {
        queries: 120,
        ..ExperimentConfig::energy_aware(ModelFamily::Gpt2, Dataset::WikiText103)
    };
    let base = run_config_with(&cfg, FailurePlan::none(), "artifacts")?;
    println!("baseline: {:.0} tok/s, coverage {:.1}%\n", base.throughput_tps, base.pass_at_k_pct);
    for (label, failures) in scenarios {
        let plan = FailurePlan::new(
            failures
                .iter()
                .map(|(d, k, t)| FailureScenario {
                    device: (*d).into(),
                    kind: *k,
                    at_s: *t,
                    recover_after_s: None,
                })
                .collect(),
        );
        let m = run_config_with(&cfg, plan, "artifacts")?;
        println!(
            "{label}\n  -> recovery {:.0} ms | throughput {:.0} tok/s ({:+.0}%) | coverage {:.1}% | queries lost: {}\n",
            m.mean_recovery_ms,
            m.throughput_tps,
            (m.throughput_tps - base.throughput_tps) / base.throughput_tps * 100.0,
            m.pass_at_k_pct,
            m.queries_lost
        );
    }

    println!("═══ Drill 2: thermal stress (guard on vs off) ═══");
    let spec = DeviceSpec::nvidia_gpu();
    let guard = ThermalGuard::default();
    for protected in [false, true] {
        let mut thermal = ThermalState::new(&spec);
        let offered = spec.idle_w + (spec.tdp_w - spec.idle_w) * 0.95;
        for _ in 0..(20.0 * 60.0 / 0.1) as usize {
            let factor = if protected {
                guard.evaluate(&spec, thermal.temp_c()).workload_factor
            } else {
                1.0
            };
            let effective = factor * thermal.hardware_throttle_factor();
            let power = spec.idle_w + (offered - spec.idle_w) * effective.max(0.05);
            thermal.step(&spec, power, 0.1);
        }
        println!(
            "guard {}: peak {:.1} °C | hw throttle events {}",
            if protected { "ON " } else { "OFF" },
            thermal.peak_c(),
            thermal.throttle_events()
        );
    }
    Ok(())
}
