#!/usr/bin/env bash
# Perf regression gate over the orchestrator micro-bench suite.
#
# Runs `cargo bench --bench orchestrator` (which writes
# BENCH_orchestrator.json at the repo root), diffs it against the
# committed baseline at benches/BENCH_orchestrator.baseline.json, and
# FAILS when any gated entry (`pgsam_assignment*`, `energy_table_build*`,
# `pgsam_warm_restart*`, `plan_cache_lookup*`, `gateway_admission*`,
# `gateway_dispatch_wave*`, `calibration_update*`,
# `energy_table_rebuild*`, `snapshot_save*`, `snapshot_restore*`,
# `replay_apply*`, `des_event_dispatch*`, `sim_step*`,
# `metro_sim_step*`, `executor_pool_dispatch*`, `load_harness_step*`,
# `obs_record_event*`, `metrics_snapshot*`, `span_record*`,
# `slo_eval*` —
# the planner-substrate, plan-cache, serving-gateway, calibration,
# snapshot/replay, discrete-event scheduler, executor-pool,
# observability, and tracing/SLO hot paths ROADMAP.md tracks)
# regresses by more than MAX_RATIO (default 10x) in mean time.
# Non-gated entries are reported but never fail the run (they are too
# machine-sensitive for a hard gate).
#
# The gate runs in two tiers:
#   * SELF-RELATIVE (always on, no baseline needed): intra-run ratio
#     contracts below compare entries from the SAME run against each
#     other, so they hold on any machine — dev laptops included.
#   * ABSOLUTE (CI-only): the cross-run diff against the committed
#     baseline. Only meaningful on the pinned CI machine; arm it there
#     with REQUIRE_BASELINE=1 so a missing baseline fails instead of
#     silently bootstrapping. On other machines the baseline diff is
#     advisory noise — the self-relative tier is the real gate.
#
# Self-relative (machine-robust, baseline-free) contracts:
#   * warm-restart amortization: the pgsam_warm_restart mean must stay
#     ≤ MAX_WARM_RATIO (default 0.5) of the cold pgsam_assignment mean;
#   * plan-cache hit cost: plan_cache_lookup must stay under
#     MAX_LOOKUP_US (default 50 µs) — a nanosecond-scale HashMap probe
#     is too machine-sensitive for the 10x ratio gate, but degrading to
#     anneal-scale means the hit path regressed to real planning work.
#   * drift-rebuild cheapness: energy_table_rebuild (overlay apply +
#     table build, the per-drift-event cost) must stay ≤
#     MAX_REBUILD_RATIO (default 3) of the cold energy_table_build mean
#     — a calibration drift event must remain cheap enough to re-plan
#     on immediately, every time it fires.
#   * checkpoint cheapness: a full snapshot round-trip (snapshot_save
#     mean + snapshot_restore mean) must stay ≤ MAX_SNAPSHOT_RATIO
#     (default 10) of the cold energy_table_build mean — if cutting a
#     checkpoint rivals the planner's own substrate costs, operators
#     will turn the checkpoint cadence off and lose crash recovery.
#   * metro scaling: the metro preset's per-component tick cost
#     (metro_sim_step mean / 105 components) must stay ≤
#     MAX_METRO_RATIO (default 4) of the edge box's (sim_step mean / 9
#     components) — the DES core promises O(dispatched events), so a
#     25x fleet may not cost superlinearly more per event.
#   * observability overhead (PR 9): the obs-armed step (sim_step_obs
#     mean) must stay ≤ MAX_OBS_RATIO (default 1.15) of the obs-off
#     sim_step mean — the recorder+profiler budget of the
#     observability contract. Self-relative by construction: both
#     entries come from the same run on the same warm engine.
#   * trace overhead (PR 10): the span-armed step (sim_step_traced
#     mean) must stay ≤ MAX_TRACE_RATIO (default 1.15) of the
#     trace-off sim_step mean — causal tracing gets the same overhead
#     budget obs does (ids are pure FNV hashes + ring inserts). Same
#     warm engine, same run, self-relative by construction.
#   * SLA-class tail ordering (PR 8, skipped under --no-run): one full
#     adversarial load-harness run (`qeil serve --load-harness`,
#     HARNESS_REQUESTS at HARNESS_OVERLOAD x capacity) must process
#     every scheduled request with the accounting closure intact (the
#     binary exits nonzero otherwise) AND keep the per-class queue-wait
#     p99 chain ordered: interactive ≤ MAX_CLASS_P99_SLACK × standard ≤
#     MAX_CLASS_P99_SLACK² × batch (default slack 1.2; links with too
#     few samples warn and skip). Self-relative by construction — the
#     classes come from the same run on the same machine. The run is
#     armed with --slo, so the per-class SLO verdict table prints into
#     the gate log; a second tiny strict run
#     (--slo-strict --slo-p99-ms 0.0001, every request over threshold
#     by construction) must exit NONZERO, locking the strict exit path.
# When a result file predates these entries (pre-PR3/PR5/PR6/PR7
# artifact via --no-run), the intra-run checks warn and skip;
# REQUIRE_BASELINE=1 (CI mode) makes missing entries fail instead.
#
# Usage:
#   scripts/check_bench.sh            # bench + compare
#   scripts/check_bench.sh --no-run   # compare an existing BENCH_orchestrator.json
#   MAX_RATIO=5 scripts/check_bench.sh
#   MAX_WARM_RATIO=0.6 scripts/check_bench.sh
#   MAX_LOOKUP_US=100 scripts/check_bench.sh
#   MAX_REBUILD_RATIO=4 scripts/check_bench.sh
#   MAX_SNAPSHOT_RATIO=15 scripts/check_bench.sh
#   MAX_METRO_RATIO=6 scripts/check_bench.sh
#   MAX_OBS_RATIO=1.25 scripts/check_bench.sh
#   MAX_TRACE_RATIO=1.25 scripts/check_bench.sh
#   HARNESS_REQUESTS=20000 HARNESS_OVERLOAD=10 scripts/check_bench.sh
#   MAX_CLASS_P99_SLACK=1.5 scripts/check_bench.sh
#   REQUIRE_BASELINE=1 scripts/check_bench.sh   # CI: fail if no baseline
#
# First run on a machine with no committed baseline: the current result
# is copied to the baseline path and the run exits 0 — commit the
# baseline to arm the gate. CI should set REQUIRE_BASELINE=1 so a
# missing baseline fails instead of silently bootstrapping.

set -euo pipefail
cd "$(dirname "$0")/.."

CURRENT=BENCH_orchestrator.json
BASELINE=benches/BENCH_orchestrator.baseline.json
MAX_RATIO="${MAX_RATIO:-10}"
MAX_WARM_RATIO="${MAX_WARM_RATIO:-0.5}"
MAX_LOOKUP_US="${MAX_LOOKUP_US:-50}"
MAX_REBUILD_RATIO="${MAX_REBUILD_RATIO:-3}"
MAX_SNAPSHOT_RATIO="${MAX_SNAPSHOT_RATIO:-10}"
MAX_METRO_RATIO="${MAX_METRO_RATIO:-4}"
MAX_OBS_RATIO="${MAX_OBS_RATIO:-1.15}"
MAX_TRACE_RATIO="${MAX_TRACE_RATIO:-1.15}"

if [[ "${1:-}" != "--no-run" ]]; then
    cargo bench --bench orchestrator
fi

if [[ ! -f "$CURRENT" ]]; then
    echo "error: $CURRENT not found (run 'cargo bench --bench orchestrator' first)" >&2
    exit 2
fi

# Intra-run gates (baseline-free and self-relative, so they also arm on
# the bootstrap run and hold on any machine): warm-restart amortization
# + plan-cache hit-cost ceiling + drift-rebuild cheapness + checkpoint
# round-trip cheapness.
python3 - "$CURRENT" "$MAX_WARM_RATIO" "$MAX_LOOKUP_US" "$MAX_REBUILD_RATIO" \
    "$MAX_SNAPSHOT_RATIO" "$MAX_METRO_RATIO" "$MAX_OBS_RATIO" "$MAX_TRACE_RATIO" \
    "${REQUIRE_BASELINE:-0}" <<'PY'
import json
import sys

cur_path, max_warm, max_lookup_us = sys.argv[1], float(sys.argv[2]), float(sys.argv[3])
max_rebuild = float(sys.argv[4])
max_snapshot = float(sys.argv[5])
max_metro = float(sys.argv[6])
max_obs = float(sys.argv[7])
max_trace = float(sys.argv[8])
strict = sys.argv[9] == "1"
with open(cur_path) as f:
    doc = json.load(f)
means = {r["name"]: float(r["mean_ns"]) for r in doc["results"]}
warm = next((v for k, v in means.items() if k.startswith("pgsam_warm_restart")), None)
cold = next((v for k, v in means.items() if k.startswith("pgsam_assignment")), None)
lookup = next((v for k, v in means.items() if k.startswith("plan_cache_lookup")), None)
build = next((v for k, v in means.items() if k.startswith("energy_table_build")), None)
rebuild = next((v for k, v in means.items() if k.startswith("energy_table_rebuild")), None)
failed = False
if warm is None or cold is None:
    # Pre-PR3 artifact (e.g. --no-run against an old result file): the
    # compare-existing workflow stays usable; CI mode insists.
    print("warm-restart gate: skipped (pgsam_warm_restart / pgsam_assignment entries "
          "missing from this result file)", file=sys.stderr)
    failed = failed or strict
else:
    ratio = warm / max(cold, 1.0)
    status = "ok" if ratio <= max_warm else "REGRESSION"
    print(f"warm-restart gate: {status} warm {warm / 1e3:.1f} us vs cold "
          f"{cold / 1e3:.1f} us ({ratio:.2f}x, budget {max_warm:g}x)")
    if ratio > max_warm:
        print("warm-restart gate FAILED: warm restart no longer amortizes the anneal",
              file=sys.stderr)
        failed = True
if lookup is None:
    print("lookup-ceiling gate: skipped (plan_cache_lookup entry missing)", file=sys.stderr)
    failed = failed or strict
else:
    status = "ok" if lookup <= max_lookup_us * 1e3 else "REGRESSION"
    print(f"lookup-ceiling gate: {status} plan_cache_lookup {lookup / 1e3:.2f} us "
          f"(ceiling {max_lookup_us:g} us)")
    if lookup > max_lookup_us * 1e3:
        print("lookup-ceiling gate FAILED: the cache hit path costs real planning work",
              file=sys.stderr)
        failed = True
if rebuild is None or build is None:
    # Pre-PR5 artifact: the compare-existing workflow stays usable; CI
    # mode insists on the calibration entries being present.
    print("drift-rebuild gate: skipped (energy_table_rebuild / energy_table_build "
          "entries missing from this result file)", file=sys.stderr)
    failed = failed or strict
else:
    ratio = rebuild / max(build, 1.0)
    status = "ok" if ratio <= max_rebuild else "REGRESSION"
    print(f"drift-rebuild gate: {status} rebuild {rebuild / 1e3:.1f} us vs build "
          f"{build / 1e3:.1f} us ({ratio:.2f}x, budget {max_rebuild:g}x)")
    if ratio > max_rebuild:
        print("drift-rebuild gate FAILED: a calibration drift event is no longer cheap "
              "enough to re-plan on immediately", file=sys.stderr)
        failed = True
save = next((v for k, v in means.items() if k.startswith("snapshot_save")), None)
restore = next((v for k, v in means.items() if k.startswith("snapshot_restore")), None)
if save is None or restore is None or build is None:
    # Pre-PR6 artifact: the compare-existing workflow stays usable; CI
    # mode insists on the snapshot entries being present.
    print("checkpoint gate: skipped (snapshot_save / snapshot_restore / "
          "energy_table_build entries missing from this result file)", file=sys.stderr)
    failed = failed or strict
else:
    ratio = (save + restore) / max(build, 1.0)
    status = "ok" if ratio <= max_snapshot else "REGRESSION"
    print(f"checkpoint gate: {status} save+restore {(save + restore) / 1e3:.1f} us vs "
          f"table build {build / 1e3:.1f} us ({ratio:.2f}x, budget {max_snapshot:g}x)")
    if ratio > max_snapshot:
        print("checkpoint gate FAILED: a snapshot round-trip now rivals planner substrate "
              "costs — checkpoint cadence becomes unaffordable", file=sys.stderr)
        failed = True
# The plain sim_step entry must exclude BOTH armed variants — a
# prefix match alone would pick up sim_step_obs or sim_step_traced
# (whichever sorts first) and gate the armed step against itself.
edge_step = next((v for k, v in means.items()
                  if k.startswith("sim_step")
                  and not k.startswith("sim_step_obs")
                  and not k.startswith("sim_step_traced")), None)
metro_step = next((v for k, v in means.items() if k.startswith("metro_sim_step")), None)
if edge_step is None or metro_step is None:
    # Pre-PR7 artifact: the compare-existing workflow stays usable; CI
    # mode insists on the DES entries being present.
    print("metro-scaling gate: skipped (sim_step / metro_sim_step entries missing "
          "from this result file)", file=sys.stderr)
    failed = failed or strict
else:
    # Components per tick = devices + 5 (4 singleton stages + one
    # window per device + fold): edge box 9, metro 105.
    edge_per_event = edge_step / 9.0
    metro_per_event = metro_step / 105.0
    ratio = metro_per_event / max(edge_per_event, 1.0)
    status = "ok" if ratio <= max_metro else "REGRESSION"
    print(f"metro-scaling gate: {status} metro {metro_per_event / 1e3:.1f} us/component vs "
          f"edge {edge_per_event / 1e3:.1f} us/component ({ratio:.2f}x, budget {max_metro:g}x)")
    if ratio > max_metro:
        print("metro-scaling gate FAILED: per-component tick cost grows superlinearly with "
              "fleet size — the DES core's O(dispatched events) contract is broken",
              file=sys.stderr)
        failed = True
obs_step = next((v for k, v in means.items() if k.startswith("sim_step_obs")), None)
if obs_step is None or edge_step is None:
    # Pre-PR9 artifact: the compare-existing workflow stays usable; CI
    # mode insists on the observability entries being present.
    print("obs-overhead gate: skipped (sim_step_obs / sim_step entries missing "
          "from this result file)", file=sys.stderr)
    failed = failed or strict
else:
    ratio = obs_step / max(edge_step, 1.0)
    status = "ok" if ratio <= max_obs else "REGRESSION"
    print(f"obs-overhead gate: {status} obs-on {obs_step / 1e3:.1f} us vs obs-off "
          f"{edge_step / 1e3:.1f} us ({ratio:.3f}x, budget {max_obs:g}x)")
    if ratio > max_obs:
        print("obs-overhead gate FAILED: recording overhead exceeds the observability "
              "contract's budget — the flight recorder/profiler is on the hot path",
              file=sys.stderr)
        failed = True
traced_step = next((v for k, v in means.items() if k.startswith("sim_step_traced")), None)
if traced_step is None or edge_step is None:
    # Pre-PR10 artifact: the compare-existing workflow stays usable; CI
    # mode insists on the tracing entries being present.
    print("trace-overhead gate: skipped (sim_step_traced / sim_step entries missing "
          "from this result file)", file=sys.stderr)
    failed = failed or strict
else:
    ratio = traced_step / max(edge_step, 1.0)
    status = "ok" if ratio <= max_trace else "REGRESSION"
    print(f"trace-overhead gate: {status} traced {traced_step / 1e3:.1f} us vs trace-off "
          f"{edge_step / 1e3:.1f} us ({ratio:.3f}x, budget {max_trace:g}x)")
    if ratio > max_trace:
        print("trace-overhead gate FAILED: span emission exceeds the tracing budget — "
              "causal tracing is on the hot path", file=sys.stderr)
        failed = True
sys.exit(1 if failed else 0)
PY

# SLA-class tail-ordering gate (PR 8): one adversarial harness run
# through the real executor pool. Needs the release binary, so it is
# skipped under --no-run (the compare-existing workflow has no
# toolchain). The harness binary itself exits nonzero on an accounting
# closure violation; the python step then checks coverage and the
# per-class p99 chain from the JSON line.
if [[ "${1:-}" != "--no-run" ]]; then
    HARNESS_REQUESTS="${HARNESS_REQUESTS:-100000}"
    HARNESS_OVERLOAD="${HARNESS_OVERLOAD:-10}"
    HARNESS_SEED="${HARNESS_SEED:-0}"
    MAX_CLASS_P99_SLACK="${MAX_CLASS_P99_SLACK:-1.2}"
    cargo build --release
    HARNESS_JSON=.harness_gate.json
    # --slo prints the per-class SLO verdict table into the gate log
    # (generous defaults: the overload run must pass non-strict).
    ./target/release/qeil serve --load-harness --slo \
        --requests "$HARNESS_REQUESTS" --overload "$HARNESS_OVERLOAD" \
        --seed "$HARNESS_SEED" --stats-json | tee /dev/stderr | tail -n 1 \
        > "$HARNESS_JSON"
    python3 - "$HARNESS_JSON" "$HARNESS_REQUESTS" "$MAX_CLASS_P99_SLACK" <<'PY'
import json
import sys

path, requests, slack = sys.argv[1], int(sys.argv[2]), float(sys.argv[3])
with open(path) as f:
    doc = json.load(f)
harness = doc["harness"]
classes = doc["classes"]
failed = False
processed = int(harness["processed"])
if processed < requests:
    print(f"harness gate FAILED: processed {processed} of {requests} scheduled "
          "requests", file=sys.stderr)
    failed = True
else:
    print(f"harness gate: processed {processed}/{requests} at "
          f"{harness['overload']:g}x overload "
          f"({harness['workers']:g} workers, {harness['wall_s']:.2f} s wall)")


def tail(name):
    h = classes[name]["queue_wait"]
    return int(h["count"]), float(h["p99_s"])


names = ("interactive", "standard", "batch")
pairs = [(n, tail(n)) for n in names]
for (an, (ac, ap)), (bn, (bc, bp)) in zip(pairs, pairs[1:]):
    if ac < 50 or bc < 50:
        print(f"harness gate: {an}<={bn} p99 link skipped "
              f"(counts {ac}/{bc} too small)", file=sys.stderr)
        continue
    status = "ok" if ap <= slack * bp else "REGRESSION"
    print(f"harness gate: {status} {an} p99 wait {ap * 1e3:.2f} ms vs {bn} "
          f"{bp * 1e3:.2f} ms (slack {slack:g}x)")
    if ap > slack * bp:
        print(f"harness gate FAILED: {an} queue-wait p99 exceeds {slack:g}x "
              f"{bn}'s — class-priority dispatch is not protecting the "
              "higher class", file=sys.stderr)
        failed = True
sys.exit(1 if failed else 0)
PY
    rm -f "$HARNESS_JSON"
    # Strict-exit lockdown (PR 10): with a 0.0001 ms p99 threshold every
    # served request is over budget by construction (deterministic
    # despite the wall-clock pool), so --slo-strict MUST exit nonzero.
    # A strict path that silently passes would let CI ship SLO
    # violations.
    if ./target/release/qeil serve --load-harness --slo-strict \
        --slo-p99-ms 0.0001 --requests 2000 --overload 4 \
        --seed "$HARNESS_SEED" > /dev/null 2>&1; then
        echo "slo-strict gate FAILED: --slo-strict exited 0 on a run where every" \
             "request violates the p99 objective" >&2
        exit 1
    else
        echo "slo-strict gate: ok (forced violation exits nonzero)"
    fi
else
    echo "harness gate: skipped (--no-run: release binary unavailable)"
fi

if [[ ! -f "$BASELINE" ]]; then
    if [[ "${REQUIRE_BASELINE:-0}" == "1" ]]; then
        echo "error: baseline $BASELINE missing and REQUIRE_BASELINE=1 (CI mode)" >&2
        echo "run the gate once on a toolchain-bearing machine and commit the baseline." >&2
        exit 3
    fi
    cp "$CURRENT" "$BASELINE"
    echo "no committed baseline found — bootstrapped $BASELINE from this run."
    echo "commit it to arm the regression gate (CI should set REQUIRE_BASELINE=1)."
    echo "note: new entries from later PRs (span_record, slo_eval, sim_step_traced"
    echo "since PR 10) bootstrap the same way — re-run on the pinned CI machine and"
    echo "commit the refreshed baseline so the absolute tier gates them too."
    exit 0
fi

python3 - "$CURRENT" "$BASELINE" "$MAX_RATIO" <<'PY'
import json
import sys

cur_path, base_path, max_ratio = sys.argv[1], sys.argv[2], float(sys.argv[3])
# plan_cache_lookup is deliberately NOT ratio-gated: a nanosecond-scale
# probe is too machine-sensitive for a cross-machine 10x bound — it is
# held to the absolute MAX_LOOKUP_US ceiling in the intra-run gate
# above instead.
GATED_PREFIXES = (
    "pgsam_assignment",
    "energy_table_build",
    "pgsam_warm_restart",
    "gateway_admission",
    "gateway_dispatch_wave",
    "calibration_update",
    "energy_table_rebuild",
    "snapshot_save",
    "snapshot_restore",
    "replay_apply",
    "des_event_dispatch",
    "sim_step",
    "metro_sim_step",
    "executor_pool_dispatch",
    "load_harness_step",
    "obs_record_event",
    "metrics_snapshot",
    "span_record",
    "slo_eval",
)


def load(path):
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: float(r["mean_ns"]) for r in doc["results"]}


cur, base = load(cur_path), load(base_path)
failed = False
print(f"bench gate: mean-time ratio vs {base_path} (fail gated > {max_ratio:g}x)")
for name in sorted(set(base) | set(cur)):
    gated = name.startswith(GATED_PREFIXES)
    if name not in cur:
        status = "MISSING" if gated else "missing"
        if gated:
            failed = True
        print(f"  {status:<10} {name} (in baseline, absent from current run)")
        continue
    if name not in base:
        print(f"  {'new':<10} {name:<48} {cur[name] / 1e3:10.1f} us (no baseline)")
        continue
    ratio = cur[name] / max(base[name], 1.0)
    status = "ok"
    if gated and ratio > max_ratio:
        status = "REGRESSION"
        failed = True
    tag = " [gated]" if gated else ""
    print(
        f"  {status:<10} {name:<48} {base[name] / 1e3:10.1f} us -> "
        f"{cur[name] / 1e3:10.1f} us  ({ratio:5.2f}x){tag}"
    )
if failed:
    print("bench gate FAILED", file=sys.stderr)
    sys.exit(1)
print("bench gate passed")
PY
