#!/usr/bin/env bash
# Perf regression gate over the orchestrator micro-bench suite.
#
# Runs `cargo bench --bench orchestrator` (which writes
# BENCH_orchestrator.json at the repo root), diffs it against the
# committed baseline at benches/BENCH_orchestrator.baseline.json, and
# FAILS when any gated entry (`pgsam_assignment*`, `energy_table_build*`
# — the two planner-substrate hot paths ROADMAP.md tracks) regresses by
# more than MAX_RATIO (default 10x) in mean time. Non-gated entries are
# reported but never fail the run (they are too machine-sensitive for a
# hard gate).
#
# Usage:
#   scripts/check_bench.sh            # bench + compare
#   scripts/check_bench.sh --no-run   # compare an existing BENCH_orchestrator.json
#   MAX_RATIO=5 scripts/check_bench.sh
#   REQUIRE_BASELINE=1 scripts/check_bench.sh   # CI: fail if no baseline
#
# First run on a machine with no committed baseline: the current result
# is copied to the baseline path and the run exits 0 — commit the
# baseline to arm the gate. CI should set REQUIRE_BASELINE=1 so a
# missing baseline fails instead of silently bootstrapping.

set -euo pipefail
cd "$(dirname "$0")/.."

CURRENT=BENCH_orchestrator.json
BASELINE=benches/BENCH_orchestrator.baseline.json
MAX_RATIO="${MAX_RATIO:-10}"

if [[ "${1:-}" != "--no-run" ]]; then
    cargo bench --bench orchestrator
fi

if [[ ! -f "$CURRENT" ]]; then
    echo "error: $CURRENT not found (run 'cargo bench --bench orchestrator' first)" >&2
    exit 2
fi

if [[ ! -f "$BASELINE" ]]; then
    if [[ "${REQUIRE_BASELINE:-0}" == "1" ]]; then
        echo "error: baseline $BASELINE missing and REQUIRE_BASELINE=1 (CI mode)" >&2
        echo "run the gate once on a toolchain-bearing machine and commit the baseline." >&2
        exit 3
    fi
    cp "$CURRENT" "$BASELINE"
    echo "no committed baseline found — bootstrapped $BASELINE from this run."
    echo "commit it to arm the regression gate (CI should set REQUIRE_BASELINE=1)."
    exit 0
fi

python3 - "$CURRENT" "$BASELINE" "$MAX_RATIO" <<'PY'
import json
import sys

cur_path, base_path, max_ratio = sys.argv[1], sys.argv[2], float(sys.argv[3])
GATED_PREFIXES = ("pgsam_assignment", "energy_table_build")


def load(path):
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: float(r["mean_ns"]) for r in doc["results"]}


cur, base = load(cur_path), load(base_path)
failed = False
print(f"bench gate: mean-time ratio vs {base_path} (fail gated > {max_ratio:g}x)")
for name in sorted(set(base) | set(cur)):
    gated = name.startswith(GATED_PREFIXES)
    if name not in cur:
        status = "MISSING" if gated else "missing"
        if gated:
            failed = True
        print(f"  {status:<10} {name} (in baseline, absent from current run)")
        continue
    if name not in base:
        print(f"  {'new':<10} {name:<48} {cur[name] / 1e3:10.1f} us (no baseline)")
        continue
    ratio = cur[name] / max(base[name], 1.0)
    status = "ok"
    if gated and ratio > max_ratio:
        status = "REGRESSION"
        failed = True
    tag = " [gated]" if gated else ""
    print(
        f"  {status:<10} {name:<48} {base[name] / 1e3:10.1f} us -> "
        f"{cur[name] / 1e3:10.1f} us  ({ratio:5.2f}x){tag}"
    )
if failed:
    print("bench gate FAILED", file=sys.stderr)
    sys.exit(1)
print("bench gate passed")
PY
