#!/usr/bin/env bash
# Crash-recovery drill matrix, headless.
#
# Builds the CLI release binary and runs `qeil replay --drill` across
# EVERY fleet preset: for each preset an uninterrupted checkpointed
# reference run is compared — bit-exactly, report and state digest —
# against recoveries that kill the coordinator at pinned ticks
# (1, mid-run, last) plus FUZZ extra per-seed fuzzed kill points, each
# restoring from the newest on-disk-equivalent checkpoint (serialized
# string round-trip) and replaying the event-log suffix.
#
# After the standard matrix, the 100-device metro preset is drilled
# with a lighter workload (it is ~12x the per-tick component count of
# edge-box, so the full matrix budget would dominate the run).
#
# FUZZ_SCHEDULE=SEED additionally runs every engine under
# `ScheduleMode::Fuzzed(SEED)` — same-tick within-stage dispatch is
# permuted per tick, so a passing drill also certifies event-ordering
# independence, not just replay determinism.
#
# After the replay matrix, a short executor-pool smoke run drives the
# adversarial load harness (`qeil serve --load-harness --slo`) at
# overload: its exit status is the accounting-closure verdict, so a
# lost or double-counted request under hostile load also fails the
# drill. --slo prints the per-class SLO verdict table (PR 10) into the
# drill log on every run — pass or fail — so a failing drill carries
# the burn-rate picture alongside the accounting dump.
#
# Exit status is the drill verdict: nonzero means some recovery
# diverged from the uninterrupted run — a replay-determinism bug — or
# the pool smoke run lost requests.
#
# Failures leave a flight-recorder trail (PR 9): a drill mismatch
# auto-dumps the reference run's recorder to stderr, and the pool smoke
# run writes its Chrome trace — which since PR 10 includes the causal
# request spans — to TRACE_OUT (kept on failure, removed on success)
# and dumps the recorder tail on a closure violation.
#
# Usage:
#   scripts/drill.sh                  # full matrix + metro, defaults
#   QUERIES=60 SAMPLES=2 scripts/drill.sh
#   SEED=7 FUZZ=4 scripts/drill.sh    # different fuzzed kill points
#   CHECKPOINT_EVERY=10 scripts/drill.sh
#   KILL_TICKS=3,17,58 scripts/drill.sh  # pin exact kill ticks
#   FUZZ_SCHEDULE=0xBEEF scripts/drill.sh  # fuzz same-tick dispatch
#   METRO_QUERIES=0 scripts/drill.sh  # skip the metro pass
#   POOL_REQUESTS=0 scripts/drill.sh  # skip the pool smoke run
#   POOL_OVERLOAD=25 scripts/drill.sh # harder pool overload

set -euo pipefail
cd "$(dirname "$0")/.."

QUERIES="${QUERIES:-80}"
SAMPLES="${SAMPLES:-4}"
SEED="${SEED:-0}"
FUZZ="${FUZZ:-2}"
CHECKPOINT_EVERY="${CHECKPOINT_EVERY:-25}"
METRO_QUERIES="${METRO_QUERIES:-24}"
METRO_SAMPLES="${METRO_SAMPLES:-2}"
POOL_REQUESTS="${POOL_REQUESTS:-20000}"
POOL_OVERLOAD="${POOL_OVERLOAD:-10}"
TRACE_OUT="${TRACE_OUT:-.drill_pool_trace.json}"

cargo build --release --quiet

common=(--seed "$SEED" --checkpoint-every "$CHECKPOINT_EVERY" --fuzz "$FUZZ")
if [[ -n "${KILL_TICKS:-}" ]]; then
    common+=(--kill-ticks "$KILL_TICKS")
fi
if [[ -n "${FUZZ_SCHEDULE:-}" ]]; then
    common+=(--fuzz-schedule "$FUZZ_SCHEDULE")
fi

status=0
./target/release/qeil replay --drill --fleet all \
    --queries "$QUERIES" --samples "$SAMPLES" "${common[@]}" || status=$?
if [[ "$status" -ne 0 ]]; then
    echo "drill matrix FAILED (exit $status): the flight-recorder dump above is the" >&2
    echo "reference run's dispatch trail leading to the state the recovery missed." >&2
    exit "$status"
fi

if [[ "$METRO_QUERIES" -gt 0 ]]; then
    ./target/release/qeil replay --drill --fleet metro \
        --queries "$METRO_QUERIES" --samples "$METRO_SAMPLES" "${common[@]}" || status=$?
    if [[ "$status" -ne 0 ]]; then
        echo "metro drill FAILED (exit $status): see the flight-recorder dump above." >&2
        exit "$status"
    fi
fi

if [[ "$POOL_REQUESTS" -gt 0 ]]; then
    ./target/release/qeil serve --load-harness --slo \
        --requests "$POOL_REQUESTS" --overload "$POOL_OVERLOAD" \
        --seed "$SEED" --stats-json --trace-out "$TRACE_OUT" || status=$?
    if [[ "$status" -ne 0 ]]; then
        echo "pool smoke run FAILED (exit $status): accounting closure violated." >&2
        echo "SLO verdict table printed above; recorder tail dumped above; full" >&2
        echo "Chrome trace (with request spans) kept at $TRACE_OUT" >&2
        exit "$status"
    fi
    rm -f "$TRACE_OUT"
fi
