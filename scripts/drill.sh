#!/usr/bin/env bash
# Crash-recovery drill matrix, headless.
#
# Builds the CLI release binary and runs `qeil replay --drill` across
# EVERY fleet preset: for each preset an uninterrupted checkpointed
# reference run is compared — bit-exactly, report and state digest —
# against recoveries that kill the coordinator at pinned ticks
# (1, mid-run, last) plus FUZZ extra per-seed fuzzed kill points, each
# restoring from the newest on-disk-equivalent checkpoint (serialized
# string round-trip) and replaying the event-log suffix.
#
# Exit status is the drill verdict: nonzero means some recovery
# diverged from the uninterrupted run — a replay-determinism bug.
#
# Usage:
#   scripts/drill.sh                  # full matrix, defaults
#   QUERIES=60 SAMPLES=2 scripts/drill.sh
#   SEED=7 FUZZ=4 scripts/drill.sh    # different fuzzed kill points
#   CHECKPOINT_EVERY=10 scripts/drill.sh
#   KILL_TICKS=3,17,58 scripts/drill.sh  # pin exact kill ticks

set -euo pipefail
cd "$(dirname "$0")/.."

QUERIES="${QUERIES:-80}"
SAMPLES="${SAMPLES:-4}"
SEED="${SEED:-0}"
FUZZ="${FUZZ:-2}"
CHECKPOINT_EVERY="${CHECKPOINT_EVERY:-25}"

cargo build --release --quiet

args=(replay --drill --fleet all
    --queries "$QUERIES" --samples "$SAMPLES" --seed "$SEED"
    --checkpoint-every "$CHECKPOINT_EVERY" --fuzz "$FUZZ")
if [[ -n "${KILL_TICKS:-}" ]]; then
    args+=(--kill-ticks "$KILL_TICKS")
fi

exec ./target/release/qeil "${args[@]}"
